//! Experiment configuration + the paper's named presets.
//!
//! Every bench/example builds an [`ExperimentConfig`] (usually from a
//! [`Preset`]) and hands it to `coordinator::run_experiment`. Configs
//! round-trip through JSON (`to_json`/`from_json`) so experiment
//! definitions can live in files and metrics records embed their full
//! provenance.

use crate::aggregation::ShardingConfig;
use crate::clients::PopulationConfig;
use crate::compression::dgc::DgcConfig;
use crate::data::DataConfig;
use crate::network::LinkConfig;
use crate::sched::SchedConfig;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// PJRT CPU running the AOT artifacts (requires `make artifacts`).
    Pjrt,
    /// Pure-Rust native MLP (artifact-free tests/benches).
    Native,
}

/// Socket-transport tuning for `afd serve`'s TCP coordinator (the
/// loopback transport ignores it).
#[derive(Clone, Debug)]
pub struct TransportConfig {
    /// Per-exchange I/O budget in seconds: an exchange (or a pending
    /// reconnect) still open after this long declares its connection
    /// dead and converts the in-flight clients into losses.
    pub io_timeout_s: f64,
    /// Session resume: replay open rounds (behind a `StateSync`
    /// preamble) to a client process that reconnects with its session
    /// token. When off, a dead connection loses its in-flight clients
    /// immediately.
    pub resume: bool,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            io_timeout_s: 600.0,
            resume: true,
        }
    }
}

/// Deterministic fault injection (see [`crate::fault`]). Off by
/// default: an empty plan installs nothing and every fault seam stays
/// a single relaxed atomic load.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Fault plan, `site:prob,...` (e.g. `"sock_read:0.05,all:0.01"`);
    /// empty = fault injection disabled.
    pub plan: String,
    /// Seed for the pure fault-decision function — independent of the
    /// experiment seed, so the same run can be replayed under
    /// different fault schedules.
    pub seed: u64,
    /// Faults per client before the engine quarantines it.
    pub quarantine_after: u32,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            plan: String::new(),
            seed: 0,
            quarantine_after: 3,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Manifest variant name (Pjrt) or a label (Native).
    pub variant: String,
    pub backend: Backend,
    /// Total federated rounds T.
    pub rounds: usize,
    /// Total client population n.
    pub num_clients: usize,
    /// Fraction of clients selected per round (paper: 30% non-IID, 10% IID).
    pub client_fraction: f64,
    /// Sub-model strategy: none | fd | afd_multi | afd_single.
    pub dropout: String,
    /// Federated Dropout Rate (fraction of activations dropped).
    pub fdr: f64,
    /// Downlink codec: raw | quant8.
    pub downlink: String,
    /// Enable DGC on the uplink (raw packed values otherwise).
    pub uplink_dgc: bool,
    pub dgc: DgcConfig,
    pub data: DataConfig,
    pub link: LinkConfig,
    /// Round scheduler: policy (sync/overselect/async_buffered) +
    /// availability churn (see [`crate::sched`]).
    pub sched: SchedConfig,
    /// Server-side aggregation sharding: shard count = auto (0, sized
    /// to the worker pool) or explicit, plus the aggregation-tree
    /// shape (see [`crate::aggregation`]).
    pub sharding: ShardingConfig,
    /// Client-population engine: lazy `(seed, id)` materialization and
    /// the residual-store byte budget (see [`crate::clients`]).
    pub population: PopulationConfig,
    /// Socket-transport timeouts and session-resume behaviour (see
    /// [`crate::transport::tcp`]).
    pub transport: TransportConfig,
    /// Deterministic fault-injection plan (see [`crate::fault`]).
    pub fault: FaultConfig,
    pub seed: u64,
    /// Evaluate the global model every k rounds (simulation-side only —
    /// evaluation costs no simulated network time).
    pub eval_every: usize,
    /// Cap on pooled-test eval batches per evaluation.
    pub eval_batch_limit: Option<usize>,
    /// Stop early once smoothed test accuracy reaches this target.
    pub target_accuracy: Option<f64>,
    /// Override the manifest's learning rate.
    pub lr_override: Option<f32>,
    /// Native backend model dims (input, hidden, classes).
    pub native_dims: (usize, usize, usize),
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            variant: "femnist_small".into(),
            backend: Backend::Pjrt,
            rounds: 100,
            num_clients: 30,
            client_fraction: 0.3,
            dropout: "afd_multi".into(),
            fdr: 0.25,
            downlink: "quant8".into(),
            uplink_dgc: true,
            dgc: DgcConfig::default(),
            data: DataConfig::default(),
            link: LinkConfig::default(),
            sched: SchedConfig::default(),
            sharding: ShardingConfig::default(),
            population: PopulationConfig::default(),
            transport: TransportConfig::default(),
            fault: FaultConfig::default(),
            seed: 0,
            eval_every: 5,
            eval_batch_limit: Some(12),
            target_accuracy: None,
            lr_override: None,
            native_dims: (32, 24, 6),
        }
    }
}

/// The paper's experiment presets (scaled; see DESIGN.md §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// Fig. 2 / Table 1 row geometry: non-IID, Multi-Model AFD, 30% cohort.
    FemnistSmallNonIid,
    ShakespeareSmallNonIid,
    Sent140SmallNonIid,
    /// Fig. 3 / Table 2 geometry: IID, Single-Model AFD, 10% cohort.
    FemnistSmallIid,
    ShakespeareSmallIid,
    Sent140SmallIid,
    /// Artifact-free native MLP smoke preset.
    NativeSmoke,
    /// NativeSmoke driven by the overselect scheduler (straggler
    /// cutting: dispatch ⌈m·(1+ε)⌉, close at m arrivals).
    NativeSmokeOverselect,
    /// NativeSmoke driven by FedBuff-style buffered async aggregation.
    NativeSmokeAsync,
    /// Cross-device population smoke: a lazily-materialized 100k-client
    /// population with a 256-client cohort, a bounded residual store and
    /// 2-level hierarchical aggregation.
    NativePopulation,
}

impl ExperimentConfig {
    pub fn preset(p: Preset) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        match p {
            Preset::FemnistSmallNonIid => {
                c.variant = "femnist_small".into();
                c.dropout = "afd_multi".into();
                c.client_fraction = 0.3;
                c.data.iid = false;
            }
            Preset::ShakespeareSmallNonIid => {
                c.variant = "shakespeare_small".into();
                c.dropout = "afd_multi".into();
                c.client_fraction = 0.3;
                c.data.iid = false;
                c.data.samples_per_client = (80, 200);
            }
            Preset::Sent140SmallNonIid => {
                c.variant = "sent140_small".into();
                c.dropout = "afd_multi".into();
                c.client_fraction = 0.3;
                c.data.iid = false;
            }
            Preset::FemnistSmallIid => {
                c.variant = "femnist_small".into();
                c.dropout = "afd_single".into();
                c.client_fraction = 0.1;
                c.data.iid = true;
            }
            Preset::ShakespeareSmallIid => {
                c.variant = "shakespeare_small".into();
                c.dropout = "afd_single".into();
                c.client_fraction = 0.1;
                c.data.iid = true;
                c.data.samples_per_client = (80, 200);
            }
            Preset::Sent140SmallIid => {
                c.variant = "sent140_small".into();
                c.dropout = "afd_single".into();
                c.client_fraction = 0.1;
                c.data.iid = true;
            }
            Preset::NativeSmoke => {
                c.variant = "native_mlp".into();
                c.backend = Backend::Native;
                c.rounds = 40;
                c.num_clients = 20;
                c.dropout = "afd_multi".into();
                c.eval_every = 2;
            }
            Preset::NativeSmokeOverselect => {
                c = ExperimentConfig::preset(Preset::NativeSmoke);
                c.sched.policy = "overselect".into();
            }
            Preset::NativeSmokeAsync => {
                c = ExperimentConfig::preset(Preset::NativeSmoke);
                c.sched.policy = "async_buffered".into();
            }
            Preset::NativePopulation => {
                c = ExperimentConfig::preset(Preset::NativeSmoke);
                c.rounds = 6;
                c.num_clients = 100_000;
                c.client_fraction = 256.0 / 100_000.0;
                c.population.lazy = true;
                c.population.store_budget_bytes = 8 << 20;
                c.sharding.tree_levels = 2;
                c.eval_every = 3;
            }
        }
        c
    }

    pub fn preset_by_name(name: &str) -> anyhow::Result<ExperimentConfig> {
        let p = match name {
            "femnist_noniid" => Preset::FemnistSmallNonIid,
            "shakespeare_noniid" => Preset::ShakespeareSmallNonIid,
            "sent140_noniid" => Preset::Sent140SmallNonIid,
            "femnist_iid" => Preset::FemnistSmallIid,
            "shakespeare_iid" => Preset::ShakespeareSmallIid,
            "sent140_iid" => Preset::Sent140SmallIid,
            "native" => Preset::NativeSmoke,
            "native_overselect" => Preset::NativeSmokeOverselect,
            "native_async" => Preset::NativeSmokeAsync,
            "native_population" => Preset::NativePopulation,
            other => anyhow::bail!("unknown preset {other:?}"),
        };
        Ok(ExperimentConfig::preset(p))
    }

    /// Cohort size m = ⌈fraction · n⌉, at least 1.
    pub fn cohort_size(&self) -> usize {
        ((self.num_clients as f64 * self.client_fraction).round() as usize)
            .clamp(1, self.num_clients)
    }

    /// A short human id like `afd_multi+quant8+dgc` (tables/logs).
    pub fn method_label(&self) -> String {
        let mut parts = vec![self.dropout.clone()];
        if self.downlink != "raw" {
            parts.push(self.downlink.clone());
        }
        if self.uplink_dgc {
            parts.push("dgc".into());
        }
        let label = parts.join("+");
        if self.sched.policy == "sync" {
            label
        } else {
            format!("{label}@{}", self.sched.policy)
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("variant", Json::Str(self.variant.clone()));
        j.set(
            "backend",
            Json::Str(
                match self.backend {
                    Backend::Pjrt => "pjrt",
                    Backend::Native => "native",
                }
                .into(),
            ),
        );
        j.set("rounds", Json::Num(self.rounds as f64));
        j.set("num_clients", Json::Num(self.num_clients as f64));
        j.set("client_fraction", Json::Num(self.client_fraction));
        j.set("dropout", Json::Str(self.dropout.clone()));
        j.set("fdr", Json::Num(self.fdr));
        j.set("downlink", Json::Str(self.downlink.clone()));
        j.set("uplink_dgc", Json::Bool(self.uplink_dgc));
        j.set("dgc_sparsity", Json::Num(self.dgc.sparsity));
        j.set("dgc_momentum", Json::Num(self.dgc.momentum as f64));
        j.set(
            "dgc_clip",
            self.dgc
                .clip_norm
                .map(|c| Json::Num(c as f64))
                .unwrap_or(Json::Null),
        );
        j.set("iid", Json::Bool(self.data.iid));
        j.set(
            "data_samples_per_client",
            Json::Arr(vec![
                Json::Num(self.data.samples_per_client.0 as f64),
                Json::Num(self.data.samples_per_client.1 as f64),
            ]),
        );
        j.set("data_test_fraction", Json::Num(self.data.test_fraction));
        j.set(
            "native_dims",
            Json::Arr(vec![
                Json::Num(self.native_dims.0 as f64),
                Json::Num(self.native_dims.1 as f64),
                Json::Num(self.native_dims.2 as f64),
            ]),
        );
        j.set(
            "lr_override",
            self.lr_override
                .map(|v| Json::Num(v as f64))
                .unwrap_or(Json::Null),
        );
        j.set(
            "link_down_mbps",
            Json::Arr(vec![
                Json::Num(self.link.down_mbps.0),
                Json::Num(self.link.down_mbps.1),
            ]),
        );
        j.set(
            "link_up_mbps",
            Json::Arr(vec![
                Json::Num(self.link.up_mbps.0),
                Json::Num(self.link.up_mbps.1),
            ]),
        );
        j.set(
            "link_device_gflops",
            Json::Arr(vec![
                Json::Num(self.link.device_gflops.0),
                Json::Num(self.link.device_gflops.1),
            ]),
        );
        j.set("link_rtt_latency_s", Json::Num(self.link.rtt_latency_s));
        j.set("link_log_uniform", Json::Bool(self.link.log_uniform));
        j.set("sched_policy", Json::Str(self.sched.policy.clone()));
        j.set("sched_over_fraction", Json::Num(self.sched.over_fraction));
        j.set(
            "sched_deadline_s",
            self.sched.deadline_s.map(Json::Num).unwrap_or(Json::Null),
        );
        j.set("sched_buffer_k", Json::Num(self.sched.buffer_k as f64));
        j.set(
            "sched_concurrency",
            Json::Num(self.sched.concurrency as f64),
        );
        j.set(
            "sched_staleness_alpha",
            Json::Num(self.sched.staleness_alpha),
        );
        j.set(
            "sharding_shard_count",
            Json::Num(self.sharding.shard_count as f64),
        );
        j.set(
            "sharding_min_shard_params",
            Json::Num(self.sharding.min_shard_params as f64),
        );
        j.set(
            "sharding_tree_levels",
            Json::Num(self.sharding.tree_levels as f64),
        );
        j.set(
            "sharding_tree_fanout",
            Json::Num(self.sharding.tree_fanout as f64),
        );
        j.set("population_lazy", Json::Bool(self.population.lazy));
        j.set(
            "population_store_budget_bytes",
            Json::Num(self.population.store_budget_bytes as f64),
        );
        j.set(
            "population_spill_dir",
            Json::Str(self.population.spill_dir.clone()),
        );
        j.set(
            "transport_io_timeout_s",
            Json::Num(self.transport.io_timeout_s),
        );
        j.set("transport_resume", Json::Bool(self.transport.resume));
        j.set("fault_plan", Json::Str(self.fault.plan.clone()));
        j.set("fault_seed", Json::Num(self.fault.seed as f64));
        j.set(
            "fault_quarantine_after",
            Json::Num(self.fault.quarantine_after as f64),
        );
        j.set("churn_enabled", Json::Bool(self.sched.churn.enabled));
        j.set(
            "churn_availability",
            Json::Num(self.sched.churn.availability),
        );
        j.set("churn_period_s", Json::Num(self.sched.churn.period_s));
        j.set("seed", Json::Num(self.seed as f64));
        j.set("eval_every", Json::Num(self.eval_every as f64));
        j.set(
            "target_accuracy",
            self.target_accuracy.map(Json::Num).unwrap_or(Json::Null),
        );
        j
    }

    /// Apply overrides parsed from a JSON object (partial configs OK).
    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        if let Some(v) = j.get("variant").and_then(|v| v.as_str()) {
            self.variant = v.to_string();
        }
        if let Some(v) = j.get("backend").and_then(|v| v.as_str()) {
            self.backend = match v {
                "pjrt" => Backend::Pjrt,
                "native" => Backend::Native,
                other => anyhow::bail!("unknown backend {other:?}"),
            };
        }
        if let Some(v) = j.get("rounds").and_then(|v| v.as_usize()) {
            self.rounds = v;
        }
        if let Some(v) = j.get("num_clients").and_then(|v| v.as_usize()) {
            self.num_clients = v;
        }
        if let Some(v) = j.get("client_fraction").and_then(|v| v.as_f64()) {
            self.client_fraction = v;
        }
        if let Some(v) = j.get("dropout").and_then(|v| v.as_str()) {
            self.dropout = v.to_string();
        }
        if let Some(v) = j.get("fdr").and_then(|v| v.as_f64()) {
            self.fdr = v;
        }
        if let Some(v) = j.get("downlink").and_then(|v| v.as_str()) {
            self.downlink = v.to_string();
        }
        if let Some(v) = j.get("uplink_dgc").and_then(|v| v.as_bool()) {
            self.uplink_dgc = v;
        }
        if let Some(v) = j.get("dgc_sparsity").and_then(|v| v.as_f64()) {
            self.dgc.sparsity = v;
        }
        if let Some(v) = j.get("dgc_momentum").and_then(|v| v.as_f64()) {
            self.dgc.momentum = v as f32;
        }
        match j.get("dgc_clip") {
            Some(Json::Null) => self.dgc.clip_norm = None,
            Some(v) => {
                if let Some(c) = v.as_f64() {
                    self.dgc.clip_norm = Some(c as f32);
                }
            }
            None => {}
        }
        if let Some(v) = j.get("iid").and_then(|v| v.as_bool()) {
            self.data.iid = v;
        }
        fn pair_usize(j: &Json, key: &str) -> Option<(usize, usize)> {
            let arr = j.get(key)?.as_arr()?;
            match arr {
                [a, b] => Some((a.as_usize()?, b.as_usize()?)),
                _ => None,
            }
        }
        fn pair_f64(j: &Json, key: &str) -> Option<(f64, f64)> {
            let arr = j.get(key)?.as_arr()?;
            match arr {
                [a, b] => Some((a.as_f64()?, b.as_f64()?)),
                _ => None,
            }
        }
        if let Some(v) = pair_usize(j, "data_samples_per_client") {
            self.data.samples_per_client = v;
        }
        if let Some(v) = j.get("data_test_fraction").and_then(|v| v.as_f64()) {
            self.data.test_fraction = v;
        }
        if let Some([d, h, c]) = j.get("native_dims").and_then(|v| v.as_arr()) {
            let dims = (d.as_usize(), h.as_usize(), c.as_usize());
            if let (Some(d), Some(h), Some(c)) = dims {
                self.native_dims = (d, h, c);
            }
        }
        if let Some(v) = j.get("lr_override").and_then(|v| v.as_f64()) {
            self.lr_override = Some(v as f32);
        }
        if let Some(v) = pair_f64(j, "link_down_mbps") {
            self.link.down_mbps = v;
        }
        if let Some(v) = pair_f64(j, "link_up_mbps") {
            self.link.up_mbps = v;
        }
        if let Some(v) = pair_f64(j, "link_device_gflops") {
            self.link.device_gflops = v;
        }
        if let Some(v) = j.get("link_rtt_latency_s").and_then(|v| v.as_f64()) {
            self.link.rtt_latency_s = v;
        }
        if let Some(v) = j.get("link_log_uniform").and_then(|v| v.as_bool()) {
            self.link.log_uniform = v;
        }
        if let Some(v) = j.get("sched_policy").and_then(|v| v.as_str()) {
            self.sched.policy = v.to_string();
        }
        if let Some(v) = j.get("sched_over_fraction").and_then(|v| v.as_f64()) {
            self.sched.over_fraction = v;
        }
        if let Some(v) = j.get("sched_deadline_s").and_then(|v| v.as_f64()) {
            self.sched.deadline_s = Some(v);
        }
        if let Some(v) = j.get("sched_buffer_k").and_then(|v| v.as_usize()) {
            self.sched.buffer_k = v;
        }
        if let Some(v) = j.get("sched_concurrency").and_then(|v| v.as_usize()) {
            self.sched.concurrency = v;
        }
        if let Some(v) = j.get("sched_staleness_alpha").and_then(|v| v.as_f64()) {
            self.sched.staleness_alpha = v;
        }
        if let Some(v) = j.get("sharding_shard_count").and_then(|v| v.as_usize()) {
            self.sharding.shard_count = v;
        }
        if let Some(v) = j.get("sharding_min_shard_params").and_then(|v| v.as_usize()) {
            self.sharding.min_shard_params = v;
        }
        if let Some(v) = j.get("sharding_tree_levels").and_then(|v| v.as_usize()) {
            self.sharding.tree_levels = v;
        }
        if let Some(v) = j.get("sharding_tree_fanout").and_then(|v| v.as_usize()) {
            self.sharding.tree_fanout = v;
        }
        if let Some(v) = j.get("population_lazy").and_then(|v| v.as_bool()) {
            self.population.lazy = v;
        }
        if let Some(v) = j
            .get("population_store_budget_bytes")
            .and_then(|v| v.as_f64())
        {
            self.population.store_budget_bytes = v as u64;
        }
        if let Some(v) = j.get("population_spill_dir").and_then(|v| v.as_str()) {
            self.population.spill_dir = v.to_string();
        }
        if let Some(v) = j.get("transport_io_timeout_s").and_then(|v| v.as_f64()) {
            self.transport.io_timeout_s = v;
        }
        if let Some(v) = j.get("transport_resume").and_then(|v| v.as_bool()) {
            self.transport.resume = v;
        }
        if let Some(v) = j.get("fault_plan").and_then(|v| v.as_str()) {
            self.fault.plan = v.to_string();
        }
        if let Some(v) = j.get("fault_seed").and_then(|v| v.as_f64()) {
            self.fault.seed = v as u64;
        }
        if let Some(v) = j.get("fault_quarantine_after").and_then(|v| v.as_usize()) {
            self.fault.quarantine_after = v as u32;
        }
        if let Some(v) = j.get("churn_enabled").and_then(|v| v.as_bool()) {
            self.sched.churn.enabled = v;
        }
        if let Some(v) = j.get("churn_availability").and_then(|v| v.as_f64()) {
            self.sched.churn.availability = v;
        }
        if let Some(v) = j.get("churn_period_s").and_then(|v| v.as_f64()) {
            self.sched.churn.period_s = v;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("eval_every").and_then(|v| v.as_usize()) {
            self.eval_every = v;
        }
        if let Some(v) = j.get("target_accuracy").and_then(|v| v.as_f64()) {
            self.target_accuracy = Some(v);
        }
        Ok(())
    }

    /// The four methods compared in every paper table, derived from a
    /// base config: NoCompression, DGC, FD+DGC, AFD+DGC.
    pub fn paper_method_grid(base: &ExperimentConfig, afd: &str) -> Vec<(String, ExperimentConfig)> {
        let mut none = base.clone();
        none.dropout = "none".into();
        none.downlink = "raw".into();
        none.uplink_dgc = false;

        let mut dgc = base.clone();
        dgc.dropout = "none".into();
        dgc.downlink = "quant8".into();
        dgc.uplink_dgc = true;

        let mut fd = base.clone();
        fd.dropout = "fd".into();
        fd.downlink = "quant8".into();
        fd.uplink_dgc = true;

        let mut afd_cfg = base.clone();
        afd_cfg.dropout = afd.into();
        afd_cfg.downlink = "quant8".into();
        afd_cfg.uplink_dgc = true;

        vec![
            ("No Compression".into(), none),
            ("DGC".into(), dgc),
            ("FD + DGC".into(), fd),
            ("AFD + DGC".into(), afd_cfg),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohort_size_bounds() {
        let mut c = ExperimentConfig::default();
        c.num_clients = 30;
        c.client_fraction = 0.3;
        assert_eq!(c.cohort_size(), 9);
        c.client_fraction = 0.0001;
        assert_eq!(c.cohort_size(), 1);
        c.client_fraction = 1.0;
        assert_eq!(c.cohort_size(), 30);
    }

    #[test]
    fn presets_match_paper_geometry() {
        let non_iid = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
        assert_eq!(non_iid.client_fraction, 0.3);
        assert!(!non_iid.data.iid);
        assert_eq!(non_iid.dropout, "afd_multi");

        let iid = ExperimentConfig::preset(Preset::FemnistSmallIid);
        assert_eq!(iid.client_fraction, 0.1);
        assert!(iid.data.iid);
        assert_eq!(iid.dropout, "afd_single");
    }

    #[test]
    fn json_roundtrip_applies_overrides() {
        let base = ExperimentConfig::default();
        let j = base.to_json();
        let mut other = ExperimentConfig::preset(Preset::NativeSmoke);
        other.apply_json(&j).unwrap();
        assert_eq!(other.variant, base.variant);
        assert_eq!(other.rounds, base.rounds);
        assert_eq!(other.dropout, base.dropout);

        let partial = crate::util::json::parse(r#"{"fdr": 0.4, "rounds": 7}"#).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_json(&partial).unwrap();
        assert_eq!(c.fdr, 0.4);
        assert_eq!(c.rounds, 7);
        assert_eq!(c.variant, "femnist_small"); // untouched
    }

    #[test]
    fn method_grid_has_paper_rows() {
        let base = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
        let grid = ExperimentConfig::paper_method_grid(&base, "afd_multi");
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0].0, "No Compression");
        assert!(!grid[0].1.uplink_dgc);
        assert_eq!(grid[0].1.downlink, "raw");
        assert_eq!(grid[3].1.dropout, "afd_multi");
        // All four share data geometry.
        for (_, c) in &grid {
            assert_eq!(c.num_clients, base.num_clients);
            assert_eq!(c.seed, base.seed);
        }
    }

    #[test]
    fn sched_presets_and_json_roundtrip() {
        let over = ExperimentConfig::preset(Preset::NativeSmokeOverselect);
        assert_eq!(over.sched.policy, "overselect");
        assert_eq!(over.backend, Backend::Native);
        let async_c = ExperimentConfig::preset_by_name("native_async").unwrap();
        assert_eq!(async_c.sched.policy, "async_buffered");

        let mut src = ExperimentConfig::default();
        src.sched.policy = "async_buffered".into();
        src.sched.buffer_k = 4;
        src.sched.staleness_alpha = 0.25;
        src.sched.churn.enabled = true;
        src.sched.churn.availability = 0.6;
        let j = src.to_json();
        let mut dst = ExperimentConfig::default();
        dst.apply_json(&j).unwrap();
        assert_eq!(dst.sched.policy, "async_buffered");
        assert_eq!(dst.sched.buffer_k, 4);
        assert_eq!(dst.sched.staleness_alpha, 0.25);
        assert!(dst.sched.churn.enabled);
        assert_eq!(dst.sched.churn.availability, 0.6);
        assert_eq!(dst.method_label(), "afd_multi+quant8+dgc@async_buffered");
    }

    #[test]
    fn sharding_json_roundtrip() {
        let mut src = ExperimentConfig::default();
        assert_eq!(src.sharding.shard_count, 0, "default is auto");
        src.sharding.shard_count = 7;
        src.sharding.min_shard_params = 1024;
        let j = src.to_json();
        let mut dst = ExperimentConfig::default();
        dst.apply_json(&j).unwrap();
        assert_eq!(dst.sharding.shard_count, 7);
        assert_eq!(dst.sharding.min_shard_params, 1024);

        // Partial configs leave the subtree untouched.
        let partial = crate::util::json::parse(r#"{"rounds": 3}"#).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_json(&partial).unwrap();
        assert_eq!(c.sharding.shard_count, 0);
    }

    #[test]
    fn population_and_tree_json_roundtrip() {
        let mut src = ExperimentConfig::default();
        assert!(!src.population.lazy, "default is the eager fleet");
        assert_eq!(src.sharding.tree_levels, 1, "default is flat aggregation");
        src.population.lazy = true;
        src.population.store_budget_bytes = 1 << 20;
        src.population.spill_dir = "/tmp/afd-spill".into();
        src.sharding.tree_levels = 3;
        src.sharding.tree_fanout = 8;
        let j = src.to_json();
        let mut dst = ExperimentConfig::default();
        dst.apply_json(&j).unwrap();
        assert!(dst.population.lazy);
        assert_eq!(dst.population.store_budget_bytes, 1 << 20);
        assert_eq!(dst.population.spill_dir, "/tmp/afd-spill");
        assert_eq!(dst.sharding.tree_levels, 3);
        assert_eq!(dst.sharding.tree_fanout, 8);

        // Partial configs leave the subtree untouched.
        let partial = crate::util::json::parse(r#"{"rounds": 3}"#).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_json(&partial).unwrap();
        assert!(!c.population.lazy);
        assert_eq!(c.sharding.tree_levels, 1);

        // The population preset wires the whole engine together.
        let p = ExperimentConfig::preset_by_name("native_population").unwrap();
        assert!(p.population.lazy);
        assert_eq!(p.num_clients, 100_000);
        assert_eq!(p.cohort_size(), 256);
        assert!(p.population.store_budget_bytes > 0);
        assert_eq!(p.sharding.tree_levels, 2);
    }

    #[test]
    fn json_roundtrip_covers_remote_client_fields() {
        // The transport handshake rebuilds a client environment from
        // the config JSON alone — every field that environment depends
        // on (model dims, data geometry, link profile, lr) must
        // survive the round-trip.
        let mut src = ExperimentConfig::preset(Preset::NativeSmoke);
        src.native_dims = (48, 32, 7);
        src.lr_override = Some(0.05);
        src.data.samples_per_client = (80, 200);
        src.data.test_fraction = 0.25;
        src.link = LinkConfig::straggler_heavy();
        src.dgc.momentum = 0.75;
        src.dgc.clip_norm = None;
        let j = src.to_json();
        let mut dst = ExperimentConfig::default();
        dst.apply_json(&j).unwrap();
        assert_eq!(dst.backend, Backend::Native);
        assert_eq!(dst.native_dims, (48, 32, 7));
        assert_eq!(dst.lr_override, Some(0.05));
        assert_eq!(dst.data.samples_per_client, (80, 200));
        assert_eq!(dst.data.test_fraction, 0.25);
        assert_eq!(dst.link.down_mbps, src.link.down_mbps);
        assert_eq!(dst.link.up_mbps, src.link.up_mbps);
        assert_eq!(dst.link.device_gflops, src.link.device_gflops);
        assert!(dst.link.log_uniform);
        assert_eq!(dst.dgc.momentum, 0.75);
        assert_eq!(dst.dgc.clip_norm, None, "explicit null must clear the clip");
        // Partial configs leave the new fields untouched.
        let partial = crate::util::json::parse(r#"{"rounds": 3}"#).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_json(&partial).unwrap();
        assert_eq!(c.native_dims, ExperimentConfig::default().native_dims);
    }

    #[test]
    fn transport_json_roundtrip() {
        let mut src = ExperimentConfig::default();
        assert_eq!(src.transport.io_timeout_s, 600.0);
        assert!(src.transport.resume, "resume is the default");
        src.transport.io_timeout_s = 2.5;
        src.transport.resume = false;
        let j = src.to_json();
        let mut dst = ExperimentConfig::default();
        dst.apply_json(&j).unwrap();
        assert_eq!(dst.transport.io_timeout_s, 2.5);
        assert!(!dst.transport.resume);

        // Partial configs leave the subtree untouched.
        let partial = crate::util::json::parse(r#"{"rounds": 3}"#).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_json(&partial).unwrap();
        assert_eq!(c.transport.io_timeout_s, 600.0);
        assert!(c.transport.resume);
    }

    #[test]
    fn fault_json_roundtrip() {
        let mut src = ExperimentConfig::default();
        assert!(src.fault.plan.is_empty(), "faults are off by default");
        assert_eq!(src.fault.quarantine_after, 3);
        src.fault.plan = "sock_read:0.05,frame_corrupt:0.01".into();
        src.fault.seed = 42;
        src.fault.quarantine_after = 5;
        let j = src.to_json();
        let mut dst = ExperimentConfig::default();
        dst.apply_json(&j).unwrap();
        assert_eq!(dst.fault.plan, src.fault.plan);
        assert_eq!(dst.fault.seed, 42);
        assert_eq!(dst.fault.quarantine_after, 5);

        // Partial configs leave the subtree untouched.
        let partial = crate::util::json::parse(r#"{"rounds": 3}"#).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_json(&partial).unwrap();
        assert!(c.fault.plan.is_empty());
        assert_eq!(c.fault.quarantine_after, 3);
    }

    #[test]
    fn method_labels() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.method_label(), "afd_multi+quant8+dgc");
        c.uplink_dgc = false;
        c.downlink = "raw".into();
        c.dropout = "none".into();
        assert_eq!(c.method_label(), "none");
    }
}
