//! Shared driver for the paper-table benches (`rust/benches/bench_*`).
//!
//! Each bench target regenerates one table/figure: it runs the paper's
//! method grid on a scaled workload, prints the measured rows next to
//! the paper's published numbers, and checks the *shape* assertions
//! (orderings/crossovers) that constitute reproduction success.
//!
//! Scaling knobs (env): `AFD_BENCH_ROUNDS`, `AFD_BENCH_SEEDS`,
//! `AFD_BENCH_CLIENTS` — defaults keep `cargo bench` minutes-scale; the
//! EXPERIMENTS.md numbers were produced with larger values.

use crate::config::ExperimentConfig;
use crate::coordinator::experiment::run_experiment;
use crate::metrics::{render_table, summarize, ExperimentReport, MethodSummary};

/// A row of the paper's published table, for side-by-side printing.
pub struct PaperRow {
    pub method: &'static str,
    pub accuracy: &'static str,
    pub time_min: f64,
    pub speedup: &'static str,
}

pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run the 4-method grid; returns (summaries, all reports per method).
pub fn run_grid(
    base: &ExperimentConfig,
    afd_kind: &str,
    seeds: usize,
) -> anyhow::Result<(Vec<MethodSummary>, Vec<(String, Vec<ExperimentReport>)>)> {
    let grid = ExperimentConfig::paper_method_grid(base, afd_kind);
    let mut rows = Vec::new();
    let mut all = Vec::new();
    for (label, cfg) in &grid {
        let mut reports = Vec::new();
        for s in 0..seeds as u64 {
            let mut c = cfg.clone();
            c.seed = base.seed + s;
            eprintln!("[bench] {label} seed {} ...", c.seed);
            reports.push(run_experiment(&c)?);
        }
        rows.push(summarize(label, &reports, base.target_accuracy));
        all.push((label.clone(), reports));
    }
    Ok((rows, all))
}

/// Print measured vs paper rows + run the shape checks.
pub fn report_against_paper(
    title: &str,
    rows: &[MethodSummary],
    paper: &[PaperRow],
) {
    println!("{}", render_table(&format!("{title} — MEASURED"), rows));
    println!("-- paper reports --");
    println!(
        "{:<18} {:>18} {:>16} {:>10}",
        "Method", "Accuracy", "Time (min)", "Speedup"
    );
    for p in paper {
        println!(
            "{:<18} {:>18} {:>16.1} {:>10}",
            p.method, p.accuracy, p.time_min, p.speedup
        );
    }
    shape_checks(title, rows);
}

/// The reproduction's success criteria (DESIGN.md §1): orderings, not
/// absolute numbers.
pub fn shape_checks(title: &str, rows: &[MethodSummary]) {
    assert_eq!(rows.len(), 4, "expected the 4-method grid");
    let time = |i: usize| rows[i].time_mean_s;
    let reached = |i: usize| rows[i].reached > 0;
    println!("-- shape checks ({title}) --");

    let mut pass = true;
    // 1. Every compressed method must beat No Compression in time.
    for i in 1..4 {
        if reached(i) && reached(0) {
            let ok = time(i) < time(0);
            println!(
                "  [{}] {} faster than No Compression ({} vs {})",
                if ok { "ok" } else { "MISS" },
                rows[i].method,
                crate::util::human_duration(time(i)),
                crate::util::human_duration(time(0)),
            );
            pass &= ok;
        }
    }
    // 2. AFD+DGC is the fastest of the compressed methods.
    if reached(3) && reached(2) {
        let ok = time(3) <= time(2) * 1.05;
        println!(
            "  [{}] AFD+DGC at least matches FD+DGC in convergence time",
            if ok { "ok" } else { "MISS" }
        );
        pass &= ok;
    }
    // 3. AFD accuracy ≥ FD accuracy (generalization claim).
    {
        let ok = rows[3].accuracy_mean >= rows[2].accuracy_mean - 0.01;
        println!(
            "  [{}] AFD accuracy ≥ FD accuracy ({:.1}% vs {:.1}%)",
            if ok { "ok" } else { "MISS" },
            rows[3].accuracy_mean * 100.0,
            rows[2].accuracy_mean * 100.0
        );
        pass &= ok;
    }
    // 4. AFD accuracy within noise of (or above) No Compression.
    {
        let ok = rows[3].accuracy_mean >= rows[0].accuracy_mean - 0.03;
        println!(
            "  [{}] AFD accuracy ≥ NoComp − 3% ({:.1}% vs {:.1}%)",
            if ok { "ok" } else { "MISS" },
            rows[3].accuracy_mean * 100.0,
            rows[0].accuracy_mean * 100.0
        );
        pass &= ok;
    }
    println!(
        "  => {}",
        if pass { "SHAPE REPRODUCED" } else { "shape deviations (see above)" }
    );
}

/// Print a Fig. 2/3-style accuracy-vs-time curve set.
pub fn print_curves(all: &[(String, Vec<ExperimentReport>)]) {
    for (label, reports) in all {
        println!("\ncurve [{label}] (sim_s, acc):");
        for (t, a) in reports[0].accuracy_curve() {
            println!("  {t:>10.1}  {a:.3}");
        }
    }
}
