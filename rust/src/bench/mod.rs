//! Micro-benchmark harness substrate (no `criterion` offline).
//!
//! `Bencher::run` measures a closure with warmup, adaptive iteration
//! counts and robust statistics (median + MAD), printing
//! criterion-style lines. Bench binaries (`rust/benches/*.rs`,
//! `harness = false`) use this for the hot-path measurements and plain
//! experiment drivers for the paper tables.

pub mod tables;

use std::time::Instant;

use crate::util::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub std_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn print(&self) {
        let t = fmt_ns(self.median_ns);
        let pm = fmt_ns(self.std_ns);
        let extra = match self.throughput {
            Some((v, unit)) => format!("  ({v:.2} {unit})"),
            None => String::new(),
        };
        println!(
            "bench {:<44} {:>12}/iter ± {:>10}  ({} iters){extra}",
            self.name, t, pm, self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bencher {
    /// Target measurement time per bench (seconds).
    pub target_s: f64,
    /// Measurement samples.
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            target_s: 1.0,
            samples: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher {
            target_s: 0.3,
            samples: 10,
            results: Vec::new(),
        }
    }

    /// Measure `f`; `bytes_per_iter` (if given) adds MiB/s throughput.
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        bytes_per_iter: Option<u64>,
        mut f: F,
    ) -> BenchResult {
        // Warmup + calibration: how many iters fit in target_s/samples?
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let per_sample = (self.target_s / self.samples as f64 / once)
            .ceil()
            .max(1.0) as u64;

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
        }
        let median = stats::quantile(&samples_ns, 0.5);
        let result = BenchResult {
            name: name.to_string(),
            iters: per_sample * self.samples as u64,
            mean_ns: stats::mean(&samples_ns),
            median_ns: median,
            std_ns: stats::std(&samples_ns),
            throughput: bytes_per_iter.map(|b| {
                ((b as f64) / (median / 1e9) / (1024.0 * 1024.0), "MiB/s")
            }),
        };
        result.print();
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write results as JSON (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    let mut j = Json::obj();
                    j.set("name", Json::Str(r.name.clone()));
                    j.set("median_ns", Json::Num(r.median_ns));
                    j.set("mean_ns", Json::Num(r.mean_ns));
                    j.set("std_ns", Json::Num(r.std_ns));
                    j.set("iters", Json::Num(r.iters as f64));
                    j
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bencher {
            target_s: 0.05,
            samples: 5,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", None, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.median_ns < 1e7, "a no-op should not take 10ms");
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_units() {
        let mut b = Bencher::quick();
        let data = vec![0u8; 1 << 20];
        let r = b.run("sum 1MiB", Some(1 << 20), || {
            std::hint::black_box(data.iter().map(|&x| x as u64).sum::<u64>());
        });
        let (v, unit) = r.throughput.unwrap();
        assert_eq!(unit, "MiB/s");
        assert!(v > 10.0, "at least 10 MiB/s expected, got {v}");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2500.0), "2.50 µs");
        assert_eq!(fmt_ns(3.5e6), "3.50 ms");
        assert_eq!(fmt_ns(2.25e9), "2.250 s");
    }
}
