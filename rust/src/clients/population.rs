//! Lazily-materialized client population + bounded residual store.
//!
//! The coordinator used to own an eager `Vec<ClientState>` — one heap
//! struct per client, fatal at cross-device scale. [`Population`]
//! replaces it with two layers:
//!
//! 1. **Pure derivation** — everything immutable about a client is a
//!    function of `(seed, client_id)` alone: its RNG stream
//!    ([`super::client_rng`]), its dataset partition
//!    ([`crate::data::lazy`]), its link parameters
//!    ([`crate::network::ClientLink::derive`]) and its churn windows
//!    (the stateless hash in [`crate::network::Availability`]). A
//!    million-client population costs no per-client memory until a
//!    client is actually sampled.
//! 2. **A bounded [`ResidualStore`]** for the mutable remainder (DGC
//!    residuals, participation counts, the advanced RNG position,
//!    recycled epoch buffers): an LRU-ordered resident map under a
//!    configurable byte budget. Cold clients are evicted — their exact
//!    state (RNG raw words, participations, DGC `u`/`v`) written to a
//!    spill file — and rehydrated bit-identically when sampled again.
//!    Reusable heap (epoch buffers, DGC shells, lazy dataset buffers)
//!    is harvested into small free pools on eviction so the warm
//!    sample→rehydrate→train→evict cycle stays allocation-free
//!    (proved by `tests/zero_alloc.rs`).
//!
//! ## Spill record format (little-endian, one record per client)
//!
//! | bytes     | field                                   |
//! |-----------|-----------------------------------------|
//! | 0..16     | RNG state (u128)                        |
//! | 16..32    | RNG inc (u128)                          |
//! | 32..40    | participations (u64)                    |
//! | 40..48    | DGC residual length `L` (u64, f32 count)|
//! | 48..48+4L | DGC `u` buffer                          |
//! | ..  +8L   | DGC `v` buffer                          |
//! | ..  +4    | CRC32 of bytes 0..48+8L (IEEE, as frames)|
//!
//! Records live in a temp file (deleted on drop) indexed by client id;
//! a client's slot is reused in place when its record fits, otherwise
//! the record is appended. Rehydration verifies the CRC trailer before
//! touching any client state: a truncated or corrupted record surfaces
//! as a typed [`SpillError`] (never garbage residuals), which the
//! scheduler converts into a per-round loss. The byte budget applies to **resident**
//! state and is enforced at round boundaries ([`Population::end_round`])
//! — within a step the in-flight cohort is materialized, so the
//! transient peak is cohort-proportional by design.
//!
//! ## Store metrics
//!
//! `RESIDUAL_STORE_HITS` counts materializations served from retained
//! state (resident or spill), `RESIDUAL_STORE_MISSES` first-ever
//! materializations, `RESIDUAL_STORE_EVICTIONS` budget evictions,
//! `RESIDUAL_STORE_SPILLED_BYTES` bytes written to the spill file, and
//! `RESIDENT_BYTES_PEAK` the resident high-water mark.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::compression::dgc::{DgcConfig, DgcState};
use crate::data::lazy::{self, Centres};
use crate::data::{ClientDataset, DataConfig, FederatedDataset, Samples};
use crate::model::manifest::VariantSpec;
use crate::runtime::EpochData;
use crate::util::rng::Pcg64;

use super::{client_rng, empty_epoch, ClientState};

/// Experiment-config subtree for the population engine.
#[derive(Clone, Debug)]
pub struct PopulationConfig {
    /// Lazy mode: derive client datasets/links on materialization
    /// instead of generating the whole fleet up front. Requires the
    /// native backend's dense-synthetic dataset family.
    pub lazy: bool,
    /// Resident-state byte budget for the residual store; `0` keeps
    /// every touched client resident (no spill file is ever created).
    pub store_budget_bytes: u64,
    /// Directory for the spill file; empty ⇒ the system temp dir.
    pub spill_dir: String,
}

impl Default for PopulationConfig {
    fn default() -> Self {
        PopulationConfig {
            lazy: false,
            store_budget_bytes: 0,
            spill_dir: String::new(),
        }
    }
}

/// Cap on each recycled-shell free pool. Pools exist to keep the warm
/// eviction/rehydration cycle allocation-free, not to cache the fleet:
/// anything beyond the cap is genuinely freed, which is what the byte
/// budget promises.
const POOL_CAP: usize = 64;

struct Entry {
    st: ClientState,
    last_use: u64,
}

/// Offset + capacity of a client's slot in the spill file.
struct Slot {
    offset: u64,
    cap: u64,
}

const SPILL_HEADER: usize = 48;
const SPILL_TRAILER: usize = 4;

/// A spill record failed validation at rehydration: truncated write,
/// on-disk corruption, or an injected storage fault. The client's
/// saved state is unusable; the scheduler reports the client lost for
/// the round instead of training on garbage residuals.
#[derive(Debug, Clone)]
pub struct SpillError {
    pub client: usize,
    pub detail: String,
}

impl SpillError {
    fn new(client: usize, detail: impl Into<String>) -> SpillError {
        SpillError {
            client,
            detail: detail.into(),
        }
    }
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "residual store: client {} spill record invalid: {}",
            self.client, self.detail
        )
    }
}

impl std::error::Error for SpillError {}

static SPILL_FILE_SEQ: AtomicU64 = AtomicU64::new(0);

struct Spill {
    file: File,
    path: PathBuf,
    slots: HashMap<usize, Slot>,
    end: u64,
}

impl Spill {
    fn create(dir: &PathBuf) -> Spill {
        let seq = SPILL_FILE_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!(
            "afd-residual-store-{}-{}.spill",
            std::process::id(),
            seq
        ));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap_or_else(|e| panic!("residual store: cannot create spill file {path:?}: {e}"));
        Spill {
            file,
            path,
            slots: HashMap::new(),
            end: 0,
        }
    }
}

impl Drop for Spill {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Bounded LRU store for mutable per-client state. See the module doc
/// for the contract and spill format.
pub struct ResidualStore {
    budget: u64,
    spill_dir: PathBuf,
    resident: HashMap<usize, Entry>,
    tick: u64,
    spill: Option<Spill>,
    // Recycled-shell pools (capacity carriers, capped at POOL_CAP).
    epoch_pool: Vec<EpochData>,
    dgc_pool: Vec<DgcState>,
    dataset_pool: Vec<ClientDataset>,
    // Reusable I/O scratch.
    byte_scratch: Vec<u8>,
    u_scratch: Vec<f32>,
    v_scratch: Vec<f32>,
    lru_scratch: Vec<(u64, usize)>,
}

impl ResidualStore {
    pub fn new(cfg: &PopulationConfig) -> ResidualStore {
        let spill_dir = if cfg.spill_dir.is_empty() {
            std::env::temp_dir()
        } else {
            PathBuf::from(&cfg.spill_dir)
        };
        ResidualStore {
            budget: cfg.store_budget_bytes,
            spill_dir,
            resident: HashMap::new(),
            tick: 0,
            spill: None,
            epoch_pool: Vec::new(),
            dgc_pool: Vec::new(),
            dataset_pool: Vec::new(),
            byte_scratch: Vec::new(),
            u_scratch: Vec::new(),
            v_scratch: Vec::new(),
            lru_scratch: Vec::new(),
        }
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    /// Clients currently paged out to the spill file.
    pub fn spilled_len(&self) -> usize {
        self.spill.as_ref().map(|s| s.slots.len()).unwrap_or(0)
    }

    /// Sum of resident clients' heap bytes (recomputed on demand —
    /// client state grows in place as DGC buffers size lazily).
    pub fn resident_bytes(&self) -> u64 {
        self.resident
            .values()
            .map(|e| e.st.resident_bytes() as u64)
            .sum()
    }

    fn is_resident(&self, id: usize) -> bool {
        self.resident.contains_key(&id)
    }

    fn touch(&mut self, id: usize) -> &mut ClientState {
        self.tick += 1;
        let e = self
            .resident
            .get_mut(&id)
            .expect("residual store: touch of non-resident client");
        e.last_use = self.tick;
        &mut e.st
    }

    fn pooled_epoch(&mut self) -> EpochData {
        self.epoch_pool.pop().unwrap_or_else(empty_epoch)
    }

    fn pooled_dgc(&mut self, cfg: &DgcConfig) -> DgcState {
        match self.dgc_pool.pop() {
            Some(mut shell) => {
                shell.restore_residuals(&[], &[]);
                // The pooled shell keeps its buffer capacity but must
                // carry the caller's config.
                if shell.config().sparsity != cfg.sparsity
                    || shell.config().momentum != cfg.momentum
                    || shell.config().clip_norm != cfg.clip_norm
                {
                    return DgcState::new(cfg.clone());
                }
                shell
            }
            None => DgcState::new(cfg.clone()),
        }
    }

    fn pooled_dataset(&mut self) -> ClientDataset {
        self.dataset_pool.pop().unwrap_or(ClientDataset {
            xs: Samples::F32(Vec::new()),
            ys: Vec::new(),
            per_sample: 0,
        })
    }

    /// Admit a freshly-built shell: if a spill record exists the saved
    /// state is loaded into it (a HIT), otherwise it stays fresh (a
    /// MISS). The entry becomes resident and most-recently used. An
    /// invalid spill record surfaces as [`SpillError`] and nothing is
    /// admitted — the client must not train from reset state while a
    /// (corrupt) saved record exists, or results silently diverge.
    fn admit(&mut self, id: usize, mut st: ClientState) -> Result<(), SpillError> {
        let rehydrated = self.load_spilled(id, &mut st)?;
        if crate::obs::enabled() {
            if rehydrated {
                crate::obs::metrics::RESIDUAL_STORE_HITS.incr();
            } else {
                crate::obs::metrics::RESIDUAL_STORE_MISSES.incr();
            }
        }
        self.tick += 1;
        self.resident.insert(
            id,
            Entry {
                st,
                last_use: self.tick,
            },
        );
        Ok(())
    }

    /// Insert `st` directly as resident (checkpoint restore: the state
    /// comes from the checkpoint body, not the spill file; any stale
    /// spill slot is forgotten so it cannot shadow the restored state).
    fn admit_raw(&mut self, id: usize, st: ClientState) {
        if let Some(spill) = &mut self.spill {
            spill.slots.remove(&id);
        }
        self.tick += 1;
        self.resident.insert(
            id,
            Entry {
                st,
                last_use: self.tick,
            },
        );
    }

    /// Read `id`'s spill record into `st`, returning whether one
    /// existed. Reuses the I/O scratch buffers — allocation-free once
    /// they are warm. The CRC trailer is verified over the whole
    /// record before any field is applied.
    fn load_spilled(&mut self, id: usize, st: &mut ClientState) -> Result<bool, SpillError> {
        let Some(spill) = &mut self.spill else {
            return Ok(false);
        };
        let Some(slot) = spill.slots.get(&id) else {
            return Ok(false);
        };
        let buf = &mut self.byte_scratch;
        buf.clear();
        buf.resize(SPILL_HEADER, 0);
        spill
            .file
            .seek(SeekFrom::Start(slot.offset))
            .and_then(|_| spill.file.read_exact(buf))
            .map_err(|e| SpillError::new(id, format!("header read failed: {e}")))?;
        let u64_at =
            |b: &[u8], o: usize| u64::from_le_bytes(b[o..o + 8].try_into().unwrap());
        let dgc_len = u64_at(buf, 40) as usize;
        let total = SPILL_HEADER + dgc_len * 8 + SPILL_TRAILER;
        if total as u64 > slot.cap {
            return Err(SpillError::new(
                id,
                format!("header corrupt: record {total} B exceeds slot {} B", slot.cap),
            ));
        }
        buf.resize(total, 0);
        spill
            .file
            .read_exact(&mut buf[SPILL_HEADER..])
            .map_err(|e| SpillError::new(id, format!("body read failed: {e}")))?;
        // Injected storage fault: corrupt one byte upstream of the CRC
        // check, exactly where real bit rot would land.
        if crate::fault::enabled()
            && crate::fault::should(crate::fault::Site::SpillCorrupt, id as u64, 0)
        {
            let pos = crate::fault::derive(crate::fault::Site::SpillCorrupt, id as u64, 1)
                as usize
                % (total - SPILL_TRAILER);
            buf[pos] ^= 0x40;
        }
        let body = total - SPILL_TRAILER;
        let want = u32::from_le_bytes(buf[body..].try_into().unwrap());
        let got = crate::transport::frame::crc32(&buf[..body]);
        if want != got {
            return Err(SpillError::new(
                id,
                format!("crc mismatch (stored {want:#010x}, computed {got:#010x})"),
            ));
        }
        Self::apply_record(st, &buf[..body], &mut self.u_scratch, &mut self.v_scratch)
            .map_err(|d| SpillError::new(id, d))?;
        Ok(true)
    }

    /// Parse one CRC-verified spill-format record (header + DGC body,
    /// no trailer) into `st`.
    fn apply_record(
        st: &mut ClientState,
        rec: &[u8],
        u_scratch: &mut Vec<f32>,
        v_scratch: &mut Vec<f32>,
    ) -> Result<(), String> {
        if rec.len() < SPILL_HEADER {
            return Err(format!("record too short ({} B)", rec.len()));
        }
        let u128_at = |b: &[u8], o: usize| {
            u128::from_le_bytes(b[o..o + 16].try_into().unwrap())
        };
        let u64_at =
            |b: &[u8], o: usize| u64::from_le_bytes(b[o..o + 8].try_into().unwrap());
        let state = u128_at(rec, 0);
        let inc = u128_at(rec, 16);
        let participations = u64_at(rec, 32) as usize;
        let dgc_len = u64_at(rec, 40) as usize;
        if rec.len() != SPILL_HEADER + dgc_len * 8 {
            return Err(format!(
                "record length {} B does not match DGC length {dgc_len}",
                rec.len()
            ));
        }
        st.rng = Pcg64::from_raw(state, inc);
        st.participations = participations;
        let body = &rec[SPILL_HEADER..];
        u_scratch.clear();
        v_scratch.clear();
        u_scratch.extend(
            body[..dgc_len * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
        );
        v_scratch.extend(
            body[dgc_len * 4..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
        );
        st.dgc.restore_residuals(u_scratch, v_scratch);
        Ok(())
    }

    /// Serialize `st`'s mutable state in spill-record layout (header +
    /// DGC body, no CRC trailer) onto `out`.
    fn push_record(st: &ClientState, out: &mut Vec<u8>) {
        let (u, v) = st.dgc.residuals();
        let (state, inc) = st.rng.to_raw();
        out.extend_from_slice(&state.to_le_bytes());
        out.extend_from_slice(&inc.to_le_bytes());
        out.extend_from_slice(&(st.participations as u64).to_le_bytes());
        out.extend_from_slice(&(u.len() as u64).to_le_bytes());
        for &x in u {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Evict `id`: write its exact mutable state to the spill file,
    /// harvest its reusable heap into the free pools, and drop it from
    /// the resident map. Returns the resident bytes released.
    fn evict(&mut self, id: usize) -> u64 {
        let Entry { mut st, .. } = self
            .resident
            .remove(&id)
            .expect("residual store: evicting non-resident client");
        let released = st.resident_bytes() as u64;
        // Serialize the record and seal it with a CRC trailer.
        let buf = &mut self.byte_scratch;
        buf.clear();
        Self::push_record(&st, buf);
        let crc = crate::transport::frame::crc32(buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        let need = buf.len() as u64;
        let spill = self
            .spill
            .get_or_insert_with(|| Spill::create(&self.spill_dir));
        let offset = match spill.slots.get_mut(&id) {
            Some(slot) if slot.cap >= need => slot.offset,
            Some(slot) => {
                let off = spill.end;
                spill.end += need;
                *slot = Slot { offset: off, cap: need };
                off
            }
            None => {
                let off = spill.end;
                spill.end += need;
                spill.slots.insert(id, Slot { offset: off, cap: need });
                off
            }
        };
        // Injected storage fault: truncate the write short of the CRC
        // trailer — the record rehydrates as a typed error, never as
        // garbage residuals.
        let write_len = if crate::fault::enabled()
            && crate::fault::should(
                crate::fault::Site::SpillTruncate,
                id as u64,
                st.participations as u64,
            ) {
            buf.len() - 3
        } else {
            buf.len()
        };
        spill
            .file
            .seek(SeekFrom::Start(offset))
            .and_then(|_| spill.file.write_all(&buf[..write_len]))
            .expect("residual store: spill write failed");
        if crate::obs::enabled() {
            crate::obs::metrics::RESIDUAL_STORE_EVICTIONS.incr();
            crate::obs::metrics::RESIDUAL_STORE_SPILLED_BYTES.add(need);
        }
        // Harvest capacity carriers into the (capped) pools.
        if self.epoch_pool.len() < POOL_CAP {
            self.epoch_pool.push(st.take_epoch_buf());
        }
        if let Some(mut ds) = st.dataset.take() {
            if self.dataset_pool.len() < POOL_CAP {
                ds.ys.clear();
                match &mut ds.xs {
                    Samples::F32(v) => v.clear(),
                    Samples::I32(v) => v.clear(),
                }
                self.dataset_pool.push(ds);
            }
        }
        if self.dgc_pool.len() < POOL_CAP {
            self.dgc_pool.push(st.take_dgc());
        }
        released
    }

    /// Enforce the byte budget: evict least-recently-used residents
    /// until the resident set fits. No-op when the budget is 0.
    fn enforce_budget(&mut self) {
        let mut total = self.resident_bytes();
        if crate::obs::enabled() {
            crate::obs::metrics::RESIDENT_BYTES_PEAK.set_max(total);
        }
        if self.budget == 0 || total <= self.budget {
            return;
        }
        let mut lru = std::mem::take(&mut self.lru_scratch);
        lru.clear();
        lru.extend(self.resident.iter().map(|(&id, e)| (e.last_use, id)));
        lru.sort_unstable();
        for &(_, id) in lru.iter() {
            if total <= self.budget {
                break;
            }
            total = total.saturating_sub(self.evict(id));
        }
        self.lru_scratch = lru;
    }
}

/// How client datasets are sourced.
enum Source {
    /// One eagerly-generated dataset shared by every materialization
    /// (the classic small-fleet mode; also what the TCP remote-client
    /// environment uses).
    Shared {
        sizes: Vec<usize>,
        dataset: Arc<FederatedDataset>,
    },
    /// Population mode: datasets derived per client from
    /// [`crate::data::lazy`]'s pure functions.
    Lazy {
        spec: VariantSpec,
        data_cfg: DataConfig,
        centres: Centres,
    },
}

/// The coordinator's client population: pure `(seed, id)` derivation
/// for immutable parameters, a bounded [`ResidualStore`] for mutable
/// state. Drop-in replacement for the old eager `Vec<ClientState>` —
/// materializing a client yields exactly the state the eager fleet
/// entry would hold (pinned by `tests/population.rs`).
pub struct Population {
    seed: u64,
    num_clients: usize,
    dgc_cfg: DgcConfig,
    source: Source,
    store: ResidualStore,
}

impl Population {
    /// Eager-data population: per-client datasets come from a shared
    /// [`FederatedDataset`]; the store still pages mutable state under
    /// the configured budget.
    pub fn eager(
        dataset: Arc<FederatedDataset>,
        dgc_cfg: DgcConfig,
        seed: u64,
        pop_cfg: &PopulationConfig,
    ) -> Population {
        let sizes: Vec<usize> = dataset.clients.iter().map(|c| c.len()).collect();
        Population {
            seed,
            num_clients: sizes.len(),
            dgc_cfg,
            source: Source::Shared { sizes, dataset },
            store: ResidualStore::new(pop_cfg),
        }
    }

    /// Lazy population: nothing per-client exists until sampled.
    pub fn lazy(
        spec: VariantSpec,
        data_cfg: DataConfig,
        dgc_cfg: DgcConfig,
        seed: u64,
        pop_cfg: &PopulationConfig,
    ) -> Population {
        let per: usize = spec.input_shape.iter().product();
        let centres = Centres::build(data_cfg.seed, spec.classes, per);
        Population {
            seed,
            num_clients: data_cfg.num_clients,
            dgc_cfg,
            source: Source::Lazy {
                spec,
                data_cfg,
                centres,
            },
            store: ResidualStore::new(pop_cfg),
        }
    }

    pub fn len(&self) -> usize {
        self.num_clients
    }

    pub fn is_empty(&self) -> bool {
        self.num_clients == 0
    }

    pub fn is_lazy(&self) -> bool {
        matches!(self.source, Source::Lazy { .. })
    }

    pub fn store(&self) -> &ResidualStore {
        &self.store
    }

    /// Pure: client `c`'s sample count (no materialization).
    pub fn num_samples(&self, c: usize) -> usize {
        match &self.source {
            Source::Shared { sizes, .. } => sizes[c],
            Source::Lazy { data_cfg, .. } => lazy::client_num_samples(data_cfg, c),
        }
    }

    /// Make client `c` resident: build a shell and admit it (spill
    /// rehydration or fresh derivation). No-op when already resident.
    fn ensure_resident(&mut self, c: usize) -> Result<(), SpillError> {
        if !self.store.is_resident(c) {
            let st = self.build_shell(c);
            self.store.admit(c, st)?;
        }
        Ok(())
    }

    /// Materialize client `c` (resident hit, spill rehydration, or
    /// fresh derivation) and return its mutable state. An invalid
    /// spill record is a typed [`SpillError`]; the scheduler converts
    /// it into a per-round loss instead of failing the run.
    pub fn try_client(&mut self, c: usize) -> Result<&mut ClientState, SpillError> {
        assert!(c < self.num_clients, "client {c} out of population range");
        self.ensure_resident(c)?;
        Ok(self.store.touch(c))
    }

    /// Materialize client `c`, panicking on storage corruption (the
    /// infallible path for callers with no loss channel; the engine
    /// uses [`Population::try_client`]).
    pub fn client(&mut self, c: usize) -> &mut ClientState {
        self.try_client(c).unwrap_or_else(|e| panic!("{e}"))
    }

    /// A fresh shell for client `c`: pure-derived immutable parameters
    /// plus pooled capacity carriers. Mutable state is the birth state
    /// — [`ResidualStore::admit`] overwrites it from the spill file
    /// when a saved record exists.
    fn build_shell(&mut self, c: usize) -> ClientState {
        let mut st = ClientState {
            id: c,
            num_samples: self.num_samples(c),
            dgc: self.store.pooled_dgc(&self.dgc_cfg),
            rng: client_rng(self.seed, c),
            participations: 0,
            epoch_buf: self.store.pooled_epoch(),
            dataset: None,
        };
        if let Source::Lazy {
            spec,
            data_cfg,
            centres,
        } = &self.source
        {
            let mut ds = self.store.pooled_dataset();
            lazy::client_dataset_into(spec, data_cfg, centres, c, &mut ds);
            st.dataset = Some(ds);
        }
        st
    }

    /// Assemble one epoch for client `c` into recycled buffers, drawing
    /// from the client's private RNG — identical draw sequence whether
    /// the data is shared or lazily derived.
    pub fn assemble_epoch(
        &mut self,
        c: usize,
        spec: &VariantSpec,
        order: &mut Vec<u32>,
        out: &mut EpochData,
    ) {
        assert!(c < self.num_clients, "client {c} out of population range");
        self.ensure_resident(c)
            .unwrap_or_else(|e| panic!("{e}"));
        match &self.source {
            Source::Shared { dataset, .. } => {
                let st = self.store.touch(c);
                dataset.clients[c].epoch_data_into(spec, &mut st.rng, order, out);
            }
            Source::Lazy { .. } => {
                let st = self.store.touch(c);
                let ClientState { dataset, rng, .. } = st;
                dataset
                    .as_ref()
                    .expect("lazy client materialized without dataset")
                    .epoch_data_into(spec, rng, order, out);
            }
        }
    }

    /// Allocating epoch assembly (the serial reference path, which
    /// deliberately mirrors the pre-store coordinator loop).
    pub fn epoch_data(&mut self, c: usize, spec: &VariantSpec) -> EpochData {
        let mut order = Vec::new();
        let mut out = empty_epoch();
        self.assemble_epoch(c, spec, &mut order, &mut out);
        out
    }

    /// Round boundary: enforce the store budget (and record the
    /// resident high-water mark).
    pub fn end_round(&mut self) {
        self.store.enforce_budget();
    }

    /// Serialize every touched client's mutable state (resident or
    /// spilled) for a coordinator checkpoint: `u64` count, then per
    /// client `u32` id, `u64` record length, spill-format record —
    /// ids ascending, so the blob is independent of hash-map iteration
    /// order and byte-stable across runs. Spilled records are
    /// CRC-verified on the way through.
    pub fn save_state(&mut self, out: &mut Vec<u8>) -> Result<(), SpillError> {
        let mut ids: Vec<usize> = self.store.resident.keys().copied().collect();
        if let Some(spill) = &self.store.spill {
            for &id in spill.slots.keys() {
                if !self.store.resident.contains_key(&id) {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        out.extend_from_slice(&(ids.len() as u64).to_le_bytes());
        let mut rec = Vec::new();
        let mut scratch = ClientState {
            id: 0,
            num_samples: 0,
            dgc: DgcState::new(self.dgc_cfg.clone()),
            rng: Pcg64::from_raw(0, 0),
            participations: 0,
            epoch_buf: empty_epoch(),
            dataset: None,
        };
        for id in ids {
            rec.clear();
            if let Some(e) = self.store.resident.get(&id) {
                ResidualStore::push_record(&e.st, &mut rec);
            } else {
                // Paged out: round-trip the spill record through the
                // CRC check without disturbing residency or LRU order.
                self.store.load_spilled(id, &mut scratch)?;
                ResidualStore::push_record(&scratch, &mut rec);
            }
            out.extend_from_slice(&(id as u32).to_le_bytes());
            out.extend_from_slice(&(rec.len() as u64).to_le_bytes());
            out.extend_from_slice(&rec);
        }
        Ok(())
    }

    /// Restore fleet state written by [`Population::save_state`] into
    /// this (freshly built) population, then enforce the byte budget.
    pub fn restore_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut off = 0usize;
        let take = |bytes: &[u8], off: &mut usize, n: usize| -> anyhow::Result<Vec<u8>> {
            if *off + n > bytes.len() {
                anyhow::bail!("population restore: truncated fleet blob");
            }
            let s = bytes[*off..*off + n].to_vec();
            *off += n;
            Ok(s)
        };
        let count = u64::from_le_bytes(take(bytes, &mut off, 8)?.try_into().unwrap()) as usize;
        let mut u_scratch = Vec::new();
        let mut v_scratch = Vec::new();
        for _ in 0..count {
            let id = u32::from_le_bytes(take(bytes, &mut off, 4)?.try_into().unwrap()) as usize;
            let len = u64::from_le_bytes(take(bytes, &mut off, 8)?.try_into().unwrap()) as usize;
            let rec = take(bytes, &mut off, len)?;
            if id >= self.num_clients {
                anyhow::bail!("population restore: client {id} outside population");
            }
            let mut st = self.build_shell(id);
            ResidualStore::apply_record(&mut st, &rec, &mut u_scratch, &mut v_scratch)
                .map_err(|d| anyhow::anyhow!("population restore: client {id}: {d}"))?;
            self.store.admit_raw(id, st);
        }
        if off != bytes.len() {
            anyhow::bail!("population restore: trailing bytes in fleet blob");
        }
        self.store.enforce_budget();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::mlp_spec;

    fn data_cfg(seed: u64, n: usize) -> DataConfig {
        DataConfig {
            num_clients: n,
            samples_per_client: (12, 20),
            iid: false,
            test_fraction: 0.2,
            seed,
        }
    }

    fn lazy_pop(seed: u64, n: usize, budget: u64) -> Population {
        let spec = mlp_spec("pop", 16, 8, 4, 4, 2, 0.1);
        Population::lazy(
            spec,
            data_cfg(seed, n),
            DgcConfig::default(),
            seed,
            &PopulationConfig {
                lazy: true,
                store_budget_bytes: budget,
                spill_dir: String::new(),
            },
        )
    }

    #[test]
    fn materialization_is_pure_per_client() {
        let mut a = lazy_pop(5, 100, 0);
        let mut b = lazy_pop(5, 100, 0);
        // Touch clients in different orders; state must agree.
        for &c in &[7usize, 99, 0, 7] {
            let _ = a.client(c);
        }
        for &c in &[0usize, 7, 99] {
            let _ = b.client(c);
        }
        for &c in &[0usize, 7, 99] {
            let (sa, sb) = (a.client(c), b.client(c));
            assert_eq!(sa.num_samples, sb.num_samples);
            assert_eq!(sa.rng.to_raw(), sb.rng.to_raw());
            let (da, db) = (sa.dataset.as_ref().unwrap(), sb.dataset.as_ref().unwrap());
            assert_eq!(da.ys, db.ys);
        }
    }

    #[test]
    fn budget_evicts_and_rehydrates_bit_identically() {
        let mut pop = lazy_pop(9, 50, 1); // 1-byte budget: evict everything
        // Mutate client 3's state: advance RNG, accumulate DGC.
        let delta: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        {
            let st = pop.client(3);
            st.participations = 5;
            for _ in 0..10 {
                st.rng.next_u64();
            }
            let _ = st.dgc.compress(&delta);
        }
        let (want_raw, want_u, want_v) = {
            let st = pop.client(3);
            let (u, v) = st.dgc.residuals();
            (st.rng.to_raw(), u.to_vec(), v.to_vec())
        };
        pop.end_round();
        assert_eq!(pop.store().resident_len(), 0, "budget must evict all");
        assert!(pop.store().spilled_len() >= 1);
        // Rehydrate: exact state back.
        let st = pop.client(3);
        assert_eq!(st.participations, 5);
        assert_eq!(st.rng.to_raw(), want_raw);
        let (u, v) = st.dgc.residuals();
        assert_eq!(u, &want_u[..]);
        assert_eq!(v, &want_v[..]);
    }

    #[test]
    fn corrupted_spill_record_is_a_typed_error() {
        let mut pop = lazy_pop(11, 20, 1);
        {
            let st = pop.client(3);
            st.participations = 2;
            let delta: Vec<f32> = (0..32).map(|i| (i as f32).cos()).collect();
            let _ = st.dgc.compress(&delta);
        }
        pop.end_round(); // 1-byte budget: evict + spill
        assert!(pop.store().spilled_len() >= 1);
        // Flip one byte of client 3's record on disk.
        let (path, offset) = {
            let spill = pop.store.spill.as_ref().unwrap();
            (spill.path.clone(), spill.slots[&3].offset)
        };
        let mut f = OpenOptions::new().read(true).write(true).open(&path).unwrap();
        let mut b = [0u8; 1];
        f.seek(SeekFrom::Start(offset + 5)).unwrap();
        f.read_exact(&mut b).unwrap();
        b[0] ^= 0x01;
        f.seek(SeekFrom::Start(offset + 5)).unwrap();
        f.write_all(&b).unwrap();
        let err = pop.try_client(3).unwrap_err();
        assert_eq!(err.client, 3);
        assert!(err.detail.contains("crc mismatch"), "{}", err.detail);
        // An untouched client still materializes fine.
        assert!(pop.try_client(4).is_ok());
    }

    #[test]
    fn truncated_spill_record_is_a_typed_error() {
        let mut pop = lazy_pop(12, 10, 1);
        {
            let st = pop.client(2);
            st.participations = 1;
        }
        pop.end_round();
        let path = pop.store.spill.as_ref().unwrap().path.clone();
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 2).unwrap();
        let err = pop.try_client(2).unwrap_err();
        assert_eq!(err.client, 2);
    }

    #[test]
    fn fleet_state_roundtrips_through_save_restore() {
        let mut pop = lazy_pop(13, 30, 1);
        let delta: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        for &c in &[1usize, 4, 9] {
            let st = pop.client(c);
            st.participations = c + 1;
            for _ in 0..c {
                st.rng.next_u64();
            }
            let _ = st.dgc.compress(&delta);
        }
        pop.end_round(); // spill everything
        let _ = pop.client(9); // mixed residency: 9 resident, 1/4 spilled
        let mut blob = Vec::new();
        pop.save_state(&mut blob).unwrap();
        let mut fresh = lazy_pop(13, 30, 1);
        fresh.restore_state(&blob).unwrap();
        for &c in &[1usize, 4, 9] {
            let want = {
                let st = pop.client(c);
                let (u, v) = st.dgc.residuals();
                (st.rng.to_raw(), st.participations, u.to_vec(), v.to_vec())
            };
            let got = {
                let st = fresh.client(c);
                let (u, v) = st.dgc.residuals();
                (st.rng.to_raw(), st.participations, u.to_vec(), v.to_vec())
            };
            assert_eq!(want, got);
        }
        // Garbage blobs are diagnosed, not loaded.
        assert!(lazy_pop(13, 30, 1).restore_state(&blob[..blob.len() - 3]).is_err());
    }

    #[test]
    fn unbudgeted_store_never_spills() {
        let mut pop = lazy_pop(2, 10, 0);
        for c in 0..10 {
            let _ = pop.client(c);
        }
        pop.end_round();
        assert_eq!(pop.store().resident_len(), 10);
        assert_eq!(pop.store().spilled_len(), 0);
    }

    #[test]
    fn eager_population_matches_fleet_entries() {
        use crate::data::lazy::generate_lazy;
        let spec = mlp_spec("pop", 16, 8, 4, 4, 2, 0.1);
        let ds = Arc::new(generate_lazy(&spec, &data_cfg(4, 8)));
        let sizes: Vec<usize> = ds.clients.iter().map(|c| c.len()).collect();
        let fleet = super::super::build_fleet(&sizes, &DgcConfig::default(), 4);
        let mut pop = Population::eager(
            ds,
            DgcConfig::default(),
            4,
            &PopulationConfig::default(),
        );
        assert_eq!(pop.len(), 8);
        for c in 0..8 {
            assert_eq!(pop.num_samples(c), fleet[c].num_samples);
            assert_eq!(pop.client(c).rng.to_raw(), fleet[c].rng.to_raw());
        }
    }
}
