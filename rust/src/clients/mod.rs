//! Client-side state the server tracks per participant.
//!
//! In a real deployment this state lives on the device; in the
//! simulation the coordinator owns it: the client's local dataset
//! handle, its DGC accumulation buffers (which must persist across the
//! rounds it participates in) and its private RNG stream.

use crate::compression::dgc::{DgcConfig, DgcState};
use crate::runtime::{BatchInput, EpochData};
use crate::util::rng::Pcg64;

pub struct ClientState {
    pub id: usize,
    /// Sample count n_c (the FedAvg weight).
    pub num_samples: usize,
    /// Persistent DGC buffers (momentum + accumulation).
    pub dgc: DgcState,
    /// Private RNG stream (batch order etc.), decorrelated per client.
    pub rng: Pcg64,
    /// Rounds this client participated in (diagnostics / Fig. 4).
    pub participations: usize,
    /// Recycled epoch-assembly buffer: `epoch_data_into` refills it at
    /// each dispatch, so a client's epoch assembly allocates nothing
    /// after its first participation.
    pub epoch_buf: EpochData,
}

fn empty_epoch() -> EpochData {
    EpochData {
        xs: BatchInput::F32(Vec::new()),
        ys: Vec::new(),
    }
}

impl ClientState {
    pub fn new(id: usize, num_samples: usize, dgc_cfg: DgcConfig, seed: u64) -> Self {
        ClientState {
            id,
            num_samples,
            dgc: DgcState::new(dgc_cfg),
            rng: Pcg64::with_stream(seed ^ 0xc11e, id as u64 + 1),
            participations: 0,
            epoch_buf: empty_epoch(),
        }
    }

    /// Move the epoch buffer out for a dispatched round (the job owns
    /// its training data on the worker thread), leaving an empty
    /// placeholder behind.
    pub fn take_epoch_buf(&mut self) -> EpochData {
        std::mem::replace(&mut self.epoch_buf, empty_epoch())
    }

    /// Return the epoch buffer after the round so the next dispatch
    /// reuses its capacity.
    pub fn put_epoch_buf(&mut self, data: EpochData) {
        self.epoch_buf = data;
    }

    /// Move the DGC buffers out for a dispatched round (the scheduler
    /// ships them with the per-client job so local training can run on
    /// a worker thread), leaving empty buffers behind.
    pub fn take_dgc(&mut self) -> DgcState {
        let fresh = DgcState::new(self.dgc.config().clone());
        std::mem::replace(&mut self.dgc, fresh)
    }

    /// Return the DGC buffers after the round (accumulation must
    /// persist across the rounds a client participates in).
    pub fn put_dgc(&mut self, st: DgcState) {
        self.dgc = st;
    }
}

/// Build the full client fleet for an experiment.
pub fn build_fleet(
    sizes: &[usize],
    dgc_cfg: &DgcConfig,
    seed: u64,
) -> Vec<ClientState> {
    sizes
        .iter()
        .enumerate()
        .map(|(id, &n)| ClientState::new(id, n, dgc_cfg.clone(), seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_has_decorrelated_rngs() {
        let mut fleet = build_fleet(&[10, 20, 30], &DgcConfig::default(), 7);
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[1].num_samples, 20);
        let a = fleet[0].rng.next_u64();
        let b = fleet[1].rng.next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn same_seed_same_fleet() {
        let mut f1 = build_fleet(&[5], &DgcConfig::default(), 3);
        let mut f2 = build_fleet(&[5], &DgcConfig::default(), 3);
        assert_eq!(f1[0].rng.next_u64(), f2[0].rng.next_u64());
    }
}
