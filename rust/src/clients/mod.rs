//! Client-side state the server tracks per participant.
//!
//! In a real deployment this state lives on the device; in the
//! simulation the coordinator owns it: the client's local dataset
//! handle, its DGC accumulation buffers (which must persist across the
//! rounds it participates in) and its private RNG stream.
//!
//! At population scale the coordinator no longer keeps a
//! `Vec<ClientState>` — the [`Population`] type (see `population.rs`
//! and `README.md` in this directory) derives immutable per-client
//! parameters purely from `(seed, client_id)` and pages the mutable
//! state through a bounded [`ResidualStore`].

use crate::compression::dgc::{DgcConfig, DgcState};
use crate::data::ClientDataset;
use crate::runtime::{BatchInput, EpochData};
use crate::util::rng::Pcg64;

pub mod population;

pub use population::{Population, PopulationConfig, ResidualStore};

/// Pure per-client RNG derivation: the client's private stream is a
/// function of `(seed, id)` alone — any client's generator can be
/// rebuilt in isolation, in any order, bit-identically. This is the
/// derivation every path uses (eager fleets, the lazy population, the
/// TCP remote-client environment), so they all agree by construction.
pub fn client_rng(seed: u64, id: usize) -> Pcg64 {
    Pcg64::with_stream(seed ^ 0xc11e, id as u64 + 1)
}

pub struct ClientState {
    pub id: usize,
    /// Sample count n_c (the FedAvg weight).
    pub num_samples: usize,
    /// Persistent DGC buffers (momentum + accumulation).
    pub dgc: DgcState,
    /// Private RNG stream (batch order etc.), decorrelated per client.
    pub rng: Pcg64,
    /// Rounds this client participated in (diagnostics / Fig. 4).
    pub participations: usize,
    /// Recycled epoch-assembly buffer: `epoch_data_into` refills it at
    /// each dispatch, so a client's epoch assembly allocates nothing
    /// after its first participation.
    pub epoch_buf: EpochData,
    /// Lazily-derived local dataset (population mode only; `None` when
    /// the experiment shares one eager [`crate::data::FederatedDataset`]).
    pub dataset: Option<ClientDataset>,
}

/// A non-allocating placeholder epoch buffer (`Vec::new` holds no
/// heap), used for the warm-path take/put exchange.
pub(crate) fn empty_epoch() -> EpochData {
    EpochData {
        xs: BatchInput::F32(Vec::new()),
        ys: Vec::new(),
    }
}

impl ClientState {
    pub fn new(id: usize, num_samples: usize, dgc_cfg: DgcConfig, seed: u64) -> Self {
        ClientState {
            id,
            num_samples,
            dgc: DgcState::new(dgc_cfg),
            rng: client_rng(seed, id),
            participations: 0,
            epoch_buf: empty_epoch(),
            dataset: None,
        }
    }

    /// Move the epoch buffer out for a dispatched round (the job owns
    /// its training data on the worker thread), leaving an empty
    /// placeholder behind. The placeholder's `Vec::new` buffers hold no
    /// heap, so the exchange itself never allocates — including when
    /// the residual store has just rehydrated this client with a
    /// pooled warm buffer (proved by `tests/zero_alloc.rs`).
    pub fn take_epoch_buf(&mut self) -> EpochData {
        std::mem::replace(&mut self.epoch_buf, empty_epoch())
    }

    /// Return the epoch buffer after the round so the next dispatch
    /// reuses its capacity.
    pub fn put_epoch_buf(&mut self, data: EpochData) {
        self.epoch_buf = data;
    }

    /// Move the DGC buffers out for a dispatched round (the scheduler
    /// ships them with the per-client job so local training can run on
    /// a worker thread), leaving empty buffers behind.
    pub fn take_dgc(&mut self) -> DgcState {
        let fresh = DgcState::new(self.dgc.config().clone());
        std::mem::replace(&mut self.dgc, fresh)
    }

    /// Return the DGC buffers after the round (accumulation must
    /// persist across the rounds a client participates in).
    pub fn put_dgc(&mut self, st: DgcState) {
        self.dgc = st;
    }

    /// Heap bytes this client's state currently holds (residual-store
    /// budget accounting).
    pub fn resident_bytes(&self) -> usize {
        let epoch = match &self.epoch_buf.xs {
            BatchInput::F32(v) => v.capacity() * 4,
            BatchInput::I32(v) => v.capacity() * 4,
        } + self.epoch_buf.ys.capacity() * 4;
        let data = self
            .dataset
            .as_ref()
            .map(|d| {
                (match &d.xs {
                    crate::data::Samples::F32(v) => v.capacity() * 4,
                    crate::data::Samples::I32(v) => v.capacity() * 4,
                }) + d.ys.capacity() * 4
            })
            .unwrap_or(0);
        std::mem::size_of::<ClientState>() + self.dgc.resident_bytes() + epoch + data
    }
}

/// Build the full client fleet for an experiment.
pub fn build_fleet(
    sizes: &[usize],
    dgc_cfg: &DgcConfig,
    seed: u64,
) -> Vec<ClientState> {
    sizes
        .iter()
        .enumerate()
        .map(|(id, &n)| ClientState::new(id, n, dgc_cfg.clone(), seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_has_decorrelated_rngs() {
        let mut fleet = build_fleet(&[10, 20, 30], &DgcConfig::default(), 7);
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[1].num_samples, 20);
        let a = fleet[0].rng.next_u64();
        let b = fleet[1].rng.next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn same_seed_same_fleet() {
        let mut f1 = build_fleet(&[5], &DgcConfig::default(), 3);
        let mut f2 = build_fleet(&[5], &DgcConfig::default(), 3);
        assert_eq!(f1[0].rng.next_u64(), f2[0].rng.next_u64());
    }

    #[test]
    fn client_rng_is_the_fleet_derivation() {
        let mut fleet = build_fleet(&[5, 5, 5], &DgcConfig::default(), 11);
        for id in 0..3 {
            let mut derived = client_rng(11, id);
            assert_eq!(fleet[id].rng.next_u64(), derived.next_u64());
        }
    }
}
