//! Flat f32 tensor used on the coordinator hot path.
//!
//! The coordinator treats a model as one contiguous `Vec<f32>` (the
//! manifest's parameter segments index into it). Everything the server
//! does per round — aggregation, delta computation, compression,
//! masking — is a pass over flat arrays, so this module keeps the ops
//! simple, allocation-conscious and autovectorizer-friendly.
//!
//! The compute-heavy training kernels (blocked GEMM, fused epilogues,
//! SGD rank updates) and the zero-allocation [`kernels::Workspace`]
//! arena live in [`kernels`]; their inner loops dispatch through the
//! runtime-selected SIMD layer in [`simd`] (AVX2 behind the `simd`
//! cargo feature, scalar reference always available, bit-identical
//! either way). See `rust/src/tensor/README.md` for the layer's
//! design notes.

pub mod kernels;
pub mod simd;

/// Shaped view metadata (shapes live in the manifest; data stays flat).
#[derive(Clone, Debug, PartialEq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }
}

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = x (copy)
pub fn copy(x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    y.copy_from_slice(x);
}

/// x *= alpha
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// out = a - b
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// a += b
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (ai, bi) in a.iter_mut().zip(b) {
        *ai += bi;
    }
}

pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
}

pub fn linf_norm(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum::<f64>() as f32
}

/// Relative L2 error ‖a−b‖/‖b‖ (artifact cross-checks).
pub fn rel_l2_error(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        num += d * d;
        den += (b[i] as f64) * (b[i] as f64);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f32::INFINITY };
    }
    (num / den).sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_sub() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        let mut out = vec![0.0; 3];
        sub(&y, &x, &mut out);
        assert_eq!(out, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn norms() {
        let x = vec![3.0, 4.0];
        assert!((l2_norm(&x) - 5.0).abs() < 1e-6);
        assert_eq!(linf_norm(&[-7.0, 2.0]), 7.0);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn relative_error() {
        let a = vec![1.0, 2.0];
        let b = vec![1.0, 2.0];
        assert_eq!(rel_l2_error(&a, &b), 0.0);
        assert_eq!(rel_l2_error(&[0.0], &[0.0]), 0.0);
        assert!(rel_l2_error(&[1.0], &[0.0]).is_infinite());
    }

    #[test]
    fn shape_numel() {
        assert_eq!(Shape(vec![2, 3, 4]).numel(), 24);
        assert_eq!(Shape(vec![]).numel(), 1);
    }
}
