//! Blocked training kernels + workspace arena — the native backend's
//! compute core.
//!
//! The scalar reference MLP (`runtime::native`, retained as
//! `NativeMlp::train_epoch_scalar`) spends its time in unblocked
//! triple loops that re-stream the weight matrices once per batch row
//! and allocate four fresh `Vec<f32>` per batch. This module provides
//! the same math as loop-structured kernels:
//!
//! * [`gemm_bias`] — `out = bias + x·W`, batch rows processed in
//!   blocks so each weight row is loaded once per *block* instead of
//!   once per *row* (the dominant memory-traffic saving for
//!   784×256-sized layers);
//! * [`relu_mask`] — fused ReLU + unit-mask epilogue;
//! * [`softmax_xent_grad`] — fused softmax → cross-entropy loss →
//!   mean gradient, in place on the logits buffer;
//! * [`backprop_hidden`] — `dh = mask ⊙ relu' ⊙ (dlog·W₂ᵀ)`;
//! * [`sgd_rank_update`] — the SGD weight update `W -= lr·AᵀG`,
//!   `b -= lr·Σ G`, fused over a block of batch rows.
//!
//! ## Numerical contract
//!
//! Every kernel accumulates along the contraction axis in strictly
//! ascending order, so [`gemm_bias`], [`relu_mask`],
//! [`softmax_xent_grad`] and [`backprop_hidden`] are bit-identical to
//! the scalar reference for **every** block size. [`sgd_rank_update`]
//! fuses a block's rank-1 updates into one pass over the weight
//! matrix: with `bb == 1` it performs exactly the reference's
//! per-sample update sequence (bit-for-bit); larger blocks change
//! rounding by ≤ 1e-5 relative error (asserted in
//! `rust/tests/kernel_equivalence.rs`) while cutting weight-matrix
//! traffic by the block factor.
//!
//! The inner loops of every kernel dispatch through
//! [`crate::tensor::simd`]: AVX2 when the `simd` cargo feature is on
//! and the CPU has it (runtime-detected once, at workspace/pool
//! construction), the scalar reference otherwise. Dispatch never
//! changes results — the SIMD implementations are bit-identical to
//! scalar (no FMA, no reassociation; see `simd.rs`), so the numerical
//! contract below holds for both paths.
//!
//! ## Workspace ownership
//!
//! [`Workspace`] is a per-job scratch arena: `take(len)` hands out a
//! recycled `Vec<f32>` (allocating only if no free buffer has enough
//! capacity), `give` returns it. A job checks buffers out, uses them,
//! and gives every one back before finishing — after the first
//! (warm-up) call, a full `train_epoch` performs **zero heap
//! allocations** (proved by `rust/tests/zero_alloc.rs` with a counting
//! allocator). [`WorkspacePool`] shares workspaces across the
//! scheduler's worker threads: a job checks one out only while it
//! executes, so peak scratch follows pool width, not cohort size, and
//! the pool keeps at most [`WorkspacePool::MAX_IDLE`] warm across
//! rounds. `take` hands out zero-filled
//! buffers; `take_uncleared` skips the memset for consumers that fully
//! overwrite their buffer before the first read.
//!
//! Beyond f32 training scratch, the arena pools the **codec scratch**
//! the compression layer draws per client round: byte sinks
//! ([`Workspace::take_bytes`] — encoder wire buffers, varint scratch),
//! `u32` sinks ([`Workspace::take_u32`] — sparse index decode) and
//! bool masks ([`Workspace::take_bool`] — coordinate masks). Sinks
//! come back with length 0 and warm capacity: checkout order is
//! deterministic per round, so after warm-up every call site receives
//! a buffer that already fits and the whole client round — train,
//! pack, encode, decode, aggregate add — allocates nothing
//! (`rust/tests/zero_alloc.rs`).

use crate::tensor::simd;

/// Default batch-row block for the SGD rank update (powers of two up
/// to this bound are dispatched to const-generic micro-kernels).
pub const DEFAULT_BATCH_BLOCK: usize = 8;

/// Largest supported batch-row block.
pub const MAX_BATCH_BLOCK: usize = 16;

// ---------------------------------------------------------------------
// Workspace arena
// ---------------------------------------------------------------------

/// Recycling arena of hot-path scratch buffers: f32 training scratch
/// plus the codec-scratch pools (byte/u32 sinks, bool masks) — see
/// module docs.
#[derive(Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    free_bytes: Vec<Vec<u8>>,
    free_u32: Vec<Vec<u32>>,
    free_bool: Vec<Vec<bool>>,
}

/// Pop the smallest free buffer whose capacity covers `len` (best-fit;
/// `None` means the caller must allocate — the warm-up path).
fn best_fit<T>(free: &mut Vec<Vec<T>>, len: usize) -> Option<Vec<T>> {
    let mut best: Option<(usize, usize)> = None; // (capacity, index)
    for (i, b) in free.iter().enumerate() {
        let cap = b.capacity();
        if cap < len {
            continue;
        }
        let better = match best {
            None => true,
            Some((bc, _)) => cap < bc,
        };
        if better {
            best = Some((cap, i));
        }
    }
    best.map(|(_, i)| free.swap_remove(i))
}

impl Workspace {
    /// Free buffers retained per pool. A `give` beyond this cap drops
    /// the buffer instead of pooling it: the engine recycles every
    /// outcome's model-sized buffers into one checked-out workspace
    /// after aggregation, and without a cap that workspace's free
    /// lists would grow by the cohort size every round for the process
    /// lifetime. The cap is far above what one client round checks
    /// out (~12 buffers), so the zero-allocation contract of a warm
    /// round is unaffected.
    pub const MAX_FREE_PER_POOL: usize = 32;

    pub fn new() -> Workspace {
        // Resolve the SIMD dispatch level before any kernel runs (the
        // probe is cached process-wide; this keeps it off hot paths).
        simd::init();
        Workspace::default()
    }

    /// Check out a zero-filled buffer of `len` elements. Reuses the
    /// smallest free buffer whose capacity suffices; allocates only
    /// when none does (the warm-up path).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut b = self.take_uncleared(len);
        b.fill(0.0);
        b
    }

    /// Like [`Workspace::take`] but skips the zero-fill: the buffer
    /// holds arbitrary stale data. Only for consumers that fully
    /// overwrite it before the first read (a model-sized memset per
    /// take is real money on the hot path).
    pub fn take_uncleared(&mut self, len: usize) -> Vec<f32> {
        match best_fit(&mut self.free, len) {
            Some(mut b) => {
                // Truncates or grows in place (only grown elements are
                // written); never reallocates since capacity >= len.
                b.resize(len, 0.0);
                b
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer to the arena for reuse.
    pub fn give(&mut self, buf: Vec<f32>) {
        if self.free.len() < Self::MAX_FREE_PER_POOL {
            self.free.push(buf);
        }
    }

    /// Check out a byte *sink*: length 0, recycled capacity. Sinks are
    /// grow-by-extend buffers (encoder wire output, varint scratch);
    /// checkout order is deterministic per round, so each call site
    /// reclaims the same buffer — grown once, warm thereafter.
    pub fn take_bytes(&mut self) -> Vec<u8> {
        let mut b = self.free_bytes.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return a byte sink to the arena.
    pub fn give_bytes(&mut self, buf: Vec<u8>) {
        if self.free_bytes.len() < Self::MAX_FREE_PER_POOL {
            self.free_bytes.push(buf);
        }
    }

    /// Check out a `u32` sink (length 0, recycled capacity).
    pub fn take_u32(&mut self) -> Vec<u32> {
        let mut b = self.free_u32.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return a `u32` sink to the arena.
    pub fn give_u32(&mut self, buf: Vec<u32>) {
        if self.free_u32.len() < Self::MAX_FREE_PER_POOL {
            self.free_u32.push(buf);
        }
    }

    /// Check out an all-`false` bool mask of `len` elements (reuses
    /// the smallest free buffer whose capacity suffices).
    pub fn take_bool(&mut self, len: usize) -> Vec<bool> {
        match best_fit(&mut self.free_bool, len) {
            Some(mut b) => {
                b.clear();
                b.resize(len, false);
                b
            }
            None => vec![false; len],
        }
    }

    /// Return a bool mask to the arena.
    pub fn give_bool(&mut self, buf: Vec<bool>) {
        if self.free_bool.len() < Self::MAX_FREE_PER_POOL {
            self.free_bool.push(buf);
        }
    }

    /// Number of free f32 buffers currently held (diagnostics/tests).
    pub fn free_buffers(&self) -> usize {
        self.free.len()
    }
}

/// Thread-safe pool of [`Workspace`]s shared across scheduler workers.
/// A job checks one out only for its execution window and restores it
/// immediately after, so at most pool-width workspaces are live at
/// once; only [`WorkspacePool::MAX_IDLE`] stay warm across rounds,
/// bounding retained scratch for the process lifetime.
#[derive(Default)]
pub struct WorkspacePool {
    free: std::sync::Mutex<Vec<Workspace>>,
}

impl WorkspacePool {
    /// Idle workspaces retained across rounds.
    pub const MAX_IDLE: usize = 32;

    pub fn new() -> WorkspacePool {
        simd::init();
        WorkspacePool::default()
    }

    pub fn checkout(&self) -> Workspace {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    pub fn restore(&self, ws: Workspace) {
        let mut g = self.free.lock().unwrap();
        if g.len() < Self::MAX_IDLE {
            g.push(ws);
        }
    }

    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------
// Forward kernels
// ---------------------------------------------------------------------

/// `out[r, :] = bias + x[r, :]·w` for `r in 0..rows`, where `x` is
/// `[rows, k]`, `w` is `[k, n]`, `bias` is `[n]` (all row-major).
///
/// Batch rows are processed in blocks of `bb` so each `w` row is
/// streamed once per block. Per-element accumulation over `k` is
/// strictly ascending (and zero inputs are skipped, matching the
/// scalar reference's sparse-input fast path), so the result is
/// bit-identical to the reference for every `bb`.
pub fn gemm_bias(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
    bb: usize,
) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), rows * n);
    let bb = bb.max(1);
    let mut r0 = 0;
    while r0 < rows {
        let blk = bb.min(rows - r0);
        for r in r0..r0 + blk {
            out[r * n..(r + 1) * n].copy_from_slice(bias);
        }
        for i in 0..k {
            let wrow = &w[i * n..(i + 1) * n];
            for r in r0..r0 + blk {
                let xi = x[r * k + i];
                if xi != 0.0 {
                    let orow = &mut out[r * n..(r + 1) * n];
                    simd::axpy_row(orow, xi, wrow);
                }
            }
        }
        r0 += blk;
    }
}

/// Fused ReLU + unit-mask epilogue: `out[r, j] = pre[r, j] · mask[j]`
/// where `pre > 0`, else `0`. Writes every element (reused scratch
/// needs no pre-clearing).
pub fn relu_mask(pre: &[f32], mask: &[f32], out: &mut [f32], rows: usize, n: usize) {
    debug_assert_eq!(pre.len(), rows * n);
    debug_assert_eq!(mask.len(), n);
    debug_assert_eq!(out.len(), rows * n);
    for r in 0..rows {
        let prow = &pre[r * n..(r + 1) * n];
        let orow = &mut out[r * n..(r + 1) * n];
        simd::relu_mask_row(prow, mask, orow);
    }
}

/// Row-wise softmax in place (shared by the fused grad kernel and the
/// eval path).
pub fn softmax_rows(logits: &mut [f32], rows: usize, c: usize) {
    debug_assert_eq!(logits.len(), rows * c);
    for r in 0..rows {
        let row = &mut logits[r * c..(r + 1) * c];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        // exp and its running sum stay scalar (reordering the z
        // accumulation would change bits); the normalization is
        // per-element and dispatches.
        simd::div_inplace(row, z);
    }
}

/// Fused softmax → cross-entropy → mean gradient, in place on the
/// logits buffer: on return `logits` holds `(softmax(logits) −
/// onehot(ys)) / rows` and the batch's mean loss is returned.
/// Operation order matches the scalar reference bit-for-bit.
pub fn softmax_xent_grad(logits: &mut [f32], ys: &[i32], rows: usize, c: usize) -> f32 {
    debug_assert_eq!(ys.len(), rows);
    softmax_rows(logits, rows, c);
    let mut loss = 0.0f32;
    for r in 0..rows {
        let yi = ys[r] as usize;
        loss += -logits[r * c + yi].max(1e-12).ln();
        logits[r * c + yi] -= 1.0;
    }
    let inv_b = 1.0 / rows as f32;
    simd::scale_inplace(logits, inv_b);
    loss * inv_b
}

/// Hidden-layer gradient: `dh[r, j] = mask[j] · (dlog[r, :]·w2[j, :])`
/// where the unit is kept and its pre-activation was positive, else 0.
/// Every element is written, so reused scratch needs no pre-clearing.
/// The dot over `c` accumulates in ascending order (bit-identical to
/// the scalar reference).
pub fn backprop_hidden(
    dlog: &[f32],
    w2: &[f32],
    mask: &[f32],
    pre: &[f32],
    dh: &mut [f32],
    rows: usize,
    h: usize,
    c: usize,
) {
    debug_assert_eq!(dlog.len(), rows * c);
    debug_assert_eq!(w2.len(), h * c);
    debug_assert_eq!(mask.len(), h);
    debug_assert_eq!(pre.len(), rows * h);
    debug_assert_eq!(dh.len(), rows * h);
    for r in 0..rows {
        let dl = &dlog[r * c..(r + 1) * c];
        let dhrow = &mut dh[r * h..(r + 1) * h];
        for j in 0..h {
            if mask[j] == 0.0 || pre[r * h + j] <= 0.0 {
                dhrow[j] = 0.0;
                continue;
            }
            let wrow = &w2[j * c..(j + 1) * c];
            let mut acc = 0.0f32;
            for (a, b) in dl.iter().zip(wrow) {
                acc += a * b;
            }
            dhrow[j] = acc * mask[j];
        }
    }
}

// ---------------------------------------------------------------------
// SGD rank update
// ---------------------------------------------------------------------

/// Const-generic micro-kernel: one block of `B` batch rows starting at
/// `r0`. Fuses the block's rank-1 contributions into a single pass
/// over `w`: `w[i, :] -= lr · Σ_{t<B} a[r0+t, i] · g[r0+t, :]`, then
/// `bias -= lr · Σ_{t<B} g[r0+t, :]`. Rows of `a` that are entirely
/// zero over the block are skipped (the reference's sparse fast path;
/// it also keeps fully-dropped units' weights bit-untouched).
fn rank_update_block<const B: usize>(
    w: &mut [f32],
    bias: &mut [f32],
    a: &[f32],
    g: &[f32],
    lr: f32,
    r0: usize,
    k: usize,
    n: usize,
) {
    let mut av = [0.0f32; B];
    for i in 0..k {
        let mut any = false;
        for t in 0..B {
            let v = a[(r0 + t) * k + i];
            av[t] = v;
            any |= v != 0.0;
        }
        if !any {
            continue;
        }
        let wrow = &mut w[i * n..(i + 1) * n];
        if B == 1 {
            // Exactly the scalar reference's op sequence:
            // w -= (lr · a) · g, one multiply-chain per element
            // (`w += (-s)·g` — the negation is exact).
            let s = lr * av[0];
            let grow = &g[r0 * n..(r0 + 1) * n];
            simd::axpy_row(wrow, -s, grow);
        } else {
            let gblk = &g[r0 * n..(r0 + B) * n];
            simd::weighted_colsum_sub(wrow, gblk, &av, lr);
        }
    }
    let gblk = &g[r0 * n..(r0 + B) * n];
    if B == 1 {
        simd::axpy_row(bias, -lr, gblk);
    } else {
        simd::colsum_sub(bias, gblk, lr);
    }
}

/// SGD weight + bias update for one layer: activations `a` `[rows, k]`
/// against gradients `g` `[rows, n]` into `w` `[k, n]` and `bias`
/// `[n]`. Batch rows are consumed in power-of-two blocks of at most
/// `bb` (clamped to [`MAX_BATCH_BLOCK`]); `bb == 1` reproduces the
/// scalar reference bit-for-bit (see module docs).
pub fn sgd_rank_update(
    w: &mut [f32],
    bias: &mut [f32],
    a: &[f32],
    g: &[f32],
    lr: f32,
    rows: usize,
    k: usize,
    n: usize,
    bb: usize,
) {
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(g.len(), rows * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    let bb = bb.clamp(1, MAX_BATCH_BLOCK);
    let mut r0 = 0;
    while r0 < rows {
        let rem = rows - r0;
        // Largest power-of-two block ≤ min(bb, remaining): every block
        // hits a const-generic micro-kernel.
        let mut blk = 1usize;
        while blk * 2 <= bb && blk * 2 <= rem {
            blk *= 2;
        }
        match blk {
            16 => rank_update_block::<16>(w, bias, a, g, lr, r0, k, n),
            8 => rank_update_block::<8>(w, bias, a, g, lr, r0, k, n),
            4 => rank_update_block::<4>(w, bias, a, g, lr, r0, k, n),
            2 => rank_update_block::<2>(w, bias, a, g, lr, r0, k, n),
            _ => rank_update_block::<1>(w, bias, a, g, lr, r0, k, n),
        }
        r0 += blk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn workspace_reuses_buffers() {
        let mut ws = Workspace::new();
        let a = ws.take(100);
        let ptr = a.as_ptr();
        ws.give(a);
        let b = ws.take(80); // smaller fits in the same buffer
        assert_eq!(b.as_ptr(), ptr);
        assert_eq!(b.len(), 80);
        assert!(b.iter().all(|&v| v == 0.0));
        ws.give(b);
        assert_eq!(ws.free_buffers(), 1);
    }

    #[test]
    fn workspace_codec_pools_recycle() {
        let mut ws = Workspace::new();
        // Byte sink: capacity survives the round-trip, length resets.
        let mut b = ws.take_bytes();
        b.extend_from_slice(&[1, 2, 3]);
        let cap = b.capacity();
        let ptr = b.as_ptr();
        ws.give_bytes(b);
        let b2 = ws.take_bytes();
        assert_eq!(b2.len(), 0);
        assert_eq!(b2.as_ptr(), ptr);
        assert!(b2.capacity() >= cap.min(3));
        ws.give_bytes(b2);
        // u32 sink: same contract.
        let mut u = ws.take_u32();
        u.push(7);
        let uptr = u.as_ptr();
        ws.give_u32(u);
        let u2 = ws.take_u32();
        assert_eq!(u2.len(), 0);
        assert_eq!(u2.as_ptr(), uptr);
        ws.give_u32(u2);
        // Bool mask: comes back all-false at the requested length.
        let mut m = ws.take_bool(10);
        m[3] = true;
        let mptr = m.as_ptr();
        ws.give_bool(m);
        let m2 = ws.take_bool(8);
        assert_eq!(m2.len(), 8);
        assert_eq!(m2.as_ptr(), mptr);
        assert!(m2.iter().all(|&x| !x));
    }

    #[test]
    fn workspace_give_caps_retained_buffers() {
        let mut ws = Workspace::new();
        for _ in 0..(Workspace::MAX_FREE_PER_POOL + 10) {
            ws.give(vec![0.0; 4]);
        }
        assert_eq!(ws.free_buffers(), Workspace::MAX_FREE_PER_POOL);
        // The sink pools honour the same cap.
        for _ in 0..(Workspace::MAX_FREE_PER_POOL + 10) {
            ws.give_bytes(Vec::new());
            ws.give_u32(Vec::new());
            ws.give_bool(Vec::new());
        }
        for _ in 0..Workspace::MAX_FREE_PER_POOL {
            ws.take_bytes();
        }
        // All retained byte sinks drained; the next take allocates
        // fresh (empty) rather than popping beyond the cap.
        assert_eq!(ws.take_bytes().capacity(), 0);
    }

    #[test]
    fn workspace_pool_roundtrip() {
        let pool = WorkspacePool::new();
        let mut ws = pool.checkout();
        ws.give(ws.take(8));
        pool.restore(ws);
        assert_eq!(pool.idle(), 1);
        let ws2 = pool.checkout();
        assert_eq!(ws2.free_buffers(), 1);
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn gemm_bias_matches_naive_for_all_blocks() {
        let (rows, k, n) = (5, 7, 6);
        let x = gauss(rows * k, 1);
        let w = gauss(k * n, 2);
        let bias = gauss(n, 3);
        let mut naive = vec![0.0f32; rows * n];
        for r in 0..rows {
            for j in 0..n {
                let mut acc = bias[j];
                for i in 0..k {
                    acc += x[r * k + i] * w[i * n + j];
                }
                naive[r * n + j] = acc;
            }
        }
        for bb in [1, 2, 3, 8] {
            let mut out = vec![0.0f32; rows * n];
            gemm_bias(&x, &w, &bias, &mut out, rows, k, n, bb);
            for (a, b) in out.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-5, "bb={bb}: {a} vs {b}");
            }
        }
        // Identical bits across block sizes (k-order never changes).
        let mut o1 = vec![0.0f32; rows * n];
        let mut o8 = vec![0.0f32; rows * n];
        gemm_bias(&x, &w, &bias, &mut o1, rows, k, n, 1);
        gemm_bias(&x, &w, &bias, &mut o8, rows, k, n, 8);
        for (a, b) in o1.iter().zip(&o8) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn softmax_grad_sums_to_zero_rows() {
        let (rows, c) = (4, 5);
        let mut logits = gauss(rows * c, 4);
        let ys = vec![0i32, 3, 1, 4];
        let loss = softmax_xent_grad(&mut logits, &ys, rows, c);
        assert!(loss > 0.0 && loss.is_finite());
        for r in 0..rows {
            let s: f32 = logits[r * c..(r + 1) * c].iter().sum();
            assert!(s.abs() < 1e-6, "row {r} grad sums to {s}");
        }
    }

    #[test]
    fn rank_update_block_one_equals_sequential_rank_ones() {
        let (rows, k, n) = (6, 4, 3);
        let a = gauss(rows * k, 5);
        let g = gauss(rows * n, 6);
        let w0 = gauss(k * n, 7);
        let b0 = gauss(n, 8);
        // Reference: per-sample updates, the scalar loop's order.
        let mut wr = w0.clone();
        let mut br = b0.clone();
        for r in 0..rows {
            for i in 0..k {
                let av = a[r * k + i];
                if av != 0.0 {
                    for j in 0..n {
                        wr[i * n + j] -= 0.1 * av * g[r * n + j];
                    }
                }
            }
            for j in 0..n {
                br[j] -= 0.1 * g[r * n + j];
            }
        }
        let mut w = w0.clone();
        let mut b = b0.clone();
        sgd_rank_update(&mut w, &mut b, &a, &g, 0.1, rows, k, n, 1);
        for (x, y) in w.iter().zip(&wr) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in b.iter().zip(&br) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Blocked: close but not necessarily bit-equal.
        let mut wb = w0.clone();
        let mut bb_ = b0.clone();
        sgd_rank_update(&mut wb, &mut bb_, &a, &g, 0.1, rows, k, n, 8);
        let err = crate::tensor::rel_l2_error(&wb, &wr);
        assert!(err < 1e-5, "blocked update drifted: {err}");
    }

    #[test]
    fn rank_update_skips_all_zero_activation_rows() {
        let (rows, k, n) = (4, 3, 2);
        let mut a = gauss(rows * k, 9);
        for r in 0..rows {
            a[r * k + 1] = 0.0; // activation column 1 dead in every row
        }
        let g = gauss(rows * n, 10);
        let w0 = gauss(k * n, 11);
        let b0 = gauss(n, 12);
        for bb in [1, 4] {
            let mut w = w0.clone();
            let mut b = b0.clone();
            sgd_rank_update(&mut w, &mut b, &a, &g, 0.2, rows, k, n, bb);
            for j in 0..n {
                assert_eq!(w[n + j].to_bits(), w0[n + j].to_bits(), "bb={bb}");
            }
        }
    }
}
