//! Runtime-dispatched SIMD layer for the compute and codec hot loops.
//!
//! Every operation here exists twice: a scalar implementation
//! ([`scalar`], always compiled — it *is* the numerical reference) and
//! an AVX2 implementation behind the `simd` cargo feature, selected
//! once per process via `is_x86_feature_detected!` (cached in an
//! atomic; [`init`] is called at workspace/pool construction so the
//! probe never sits on a hot path). Without the feature — or on a CPU
//! without AVX2, or on a non-x86 target — every call resolves to the
//! scalar path. NEON (aarch64) is a stub: [`detect`] documents where
//! its probe goes; until implementations are written aarch64 falls
//! back to scalar.
//!
//! ## Bit-identity contract
//!
//! The SIMD implementations are **bit-identical** to their scalar
//! references, not merely close:
//!
//! * no FMA contraction — every `a*b + c` is a rounded multiply
//!   followed by a rounded add, exactly like the scalar code;
//! * no reassociation — reductions that the scalar code accumulates in
//!   ascending order (`weighted_colsum_sub`'s per-column sums, the
//!   FWHT butterflies) keep that order per output element and only
//!   vectorize across independent elements;
//! * order-insensitive reductions ([`absmax`]) are the one exception:
//!   `max` over non-negative values is the same for any grouping, and
//!   the lane ordering matches scalar `f32::max`'s NaN-ignoring
//!   semantics (`maxps(x, acc)` keeps `acc` when `x` is NaN);
//! * integer/byte ops ([`quantize_block`], [`dequantize_block`],
//!   [`gather_extend`]) are exact by construction, so codec bytes are
//!   identical between paths.
//!
//! Rounding in [`quantize_block`] is ties-to-even via the shared
//! [`quantize_unit`] helper (the `1.5·2²³` magic-constant trick, exact
//! for `|t| ≤ 127`), which both paths — and the vectorized
//! `_mm256_add_ps`/`_mm256_sub_ps` sequence — compute identically.
//! `rust/tests/simd_conformance.rs` enforces all of this
//! property-style against [`scalar`]; the `--features simd` CI job
//! runs the whole suite under the feature.

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction set the dispatcher resolved to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar loops (the reference; always available).
    Scalar,
    /// AVX2 256-bit paths (x86-64, `simd` feature, runtime-detected).
    Avx2,
}

const UNPROBED: u8 = 0;
const LVL_SCALAR: u8 = 1;
const LVL_AVX2: u8 = 2;

static LEVEL: AtomicU8 = AtomicU8::new(UNPROBED);

/// Probe the CPU once and cache the dispatch level. Called from
/// `Workspace`/`WorkspacePool` construction and `Experiment::build`;
/// safe to call repeatedly.
pub fn init() -> SimdLevel {
    let lvl = detect();
    let code = match lvl {
        SimdLevel::Avx2 => LVL_AVX2,
        SimdLevel::Scalar => LVL_SCALAR,
    };
    LEVEL.store(code, Ordering::Relaxed);
    lvl
}

/// The cached dispatch level (probing on first use if [`init`] has not
/// run yet).
#[inline]
pub fn level() -> SimdLevel {
    match LEVEL.load(Ordering::Relaxed) {
        LVL_AVX2 => SimdLevel::Avx2,
        LVL_SCALAR => SimdLevel::Scalar,
        _ => init(),
    }
}

fn detect() -> SimdLevel {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    // NEON stub: an aarch64 probe (`is_aarch64_feature_detected!`)
    // slots in here once NEON implementations exist; until then
    // aarch64 dispatches to scalar.
    SimdLevel::Scalar
}

/// Name of the active dispatch path (bench metadata).
pub fn active_name() -> &'static str {
    match level() {
        SimdLevel::Avx2 => "avx2",
        SimdLevel::Scalar => "scalar",
    }
}

/// CPU feature set detected on this machine, independent of the
/// `simd` feature gate and of the dispatch decision — recorded in the
/// bench JSON schemas so measured numbers carry their hardware
/// context.
pub fn cpu_features() -> Vec<&'static str> {
    let mut out = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, have) in [
            ("sse2", std::arch::is_x86_feature_detected!("sse2")),
            ("sse4.2", std::arch::is_x86_feature_detected!("sse4.2")),
            ("avx", std::arch::is_x86_feature_detected!("avx")),
            ("avx2", std::arch::is_x86_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
        ] {
            if have {
                out.push(name);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        out.push("neon");
    }
    out
}

/// `1.5·2²³`: adding then subtracting this constant rounds an f32 with
/// `|t| < 2²²` to the nearest integer, ties to even — two IEEE adds
/// that the scalar and AVX2 paths perform identically.
pub const ROUND_MAGIC: f32 = 12_582_912.0;

/// Quantize one rotated coordinate: round `t` ties-to-even, clamp to
/// `[-127, 127]` in the float domain, cast. `|t| ≤ 127` by
/// construction (`t = v·127/max|v|`); non-finite `t` degrades the same
/// way on both paths (`min`/`max` ignore NaN identically).
#[inline]
pub fn quantize_unit(t: f32) -> u8 {
    let r = (t + ROUND_MAGIC) - ROUND_MAGIC;
    let c = r.min(127.0).max(-127.0);
    (c as i8) as u8
}

/// Scalar reference implementations — always compiled; the conformance
/// suite compares the dispatched entry points against these.
pub mod scalar {
    /// `out[j] += x · w[j]`.
    #[inline]
    pub fn axpy_row(out: &mut [f32], x: f32, w: &[f32]) {
        for (o, &wv) in out.iter_mut().zip(w) {
            *o += x * wv;
        }
    }

    /// `out[j] = pre[j] > 0 ? pre[j]·mask[j] : 0`.
    #[inline]
    pub fn relu_mask_row(pre: &[f32], mask: &[f32], out: &mut [f32]) {
        for ((o, &v), &m) in out.iter_mut().zip(pre).zip(mask) {
            *o = if v > 0.0 { v * m } else { 0.0 };
        }
    }

    /// `v[i] /= z` (kept a true division: `·(1/z)` rounds differently).
    #[inline]
    pub fn div_inplace(v: &mut [f32], z: f32) {
        for x in v.iter_mut() {
            *x /= z;
        }
    }

    /// `v[i] *= a`.
    #[inline]
    pub fn scale_inplace(v: &mut [f32], a: f32) {
        for x in v.iter_mut() {
            *x *= a;
        }
    }

    /// `v[i] *= s[i]` (Rademacher diagonal application).
    #[inline]
    pub fn mul_inplace(v: &mut [f32], s: &[f32]) {
        for (x, &sv) in v.iter_mut().zip(s) {
            *x *= sv;
        }
    }

    /// `w[j] -= lr · Σ_t av[t]·g[t·n + j]`, `t` ascending, accumulator
    /// starting at 0.0 (the blocked SGD rank update's inner loops).
    pub fn weighted_colsum_sub(w: &mut [f32], g: &[f32], av: &[f32], lr: f32) {
        let n = w.len();
        debug_assert_eq!(g.len(), av.len() * n);
        for j in 0..n {
            let mut acc = 0.0f32;
            for (t, &a) in av.iter().enumerate() {
                acc += a * g[t * n + j];
            }
            w[j] -= lr * acc;
        }
    }

    /// `bias[j] -= lr · Σ_t g[t·n + j]`, `t` ascending.
    pub fn colsum_sub(bias: &mut [f32], g: &[f32], lr: f32) {
        let n = bias.len();
        if n == 0 {
            return;
        }
        debug_assert_eq!(g.len() % n, 0);
        let rows = g.len() / n;
        for j in 0..n {
            let mut acc = 0.0f32;
            for t in 0..rows {
                acc += g[t * n + j];
            }
            bias[j] -= lr * acc;
        }
    }

    /// In-place unnormalized fast Walsh–Hadamard transform.
    pub fn fwht(v: &mut [f32]) {
        let n = v.len();
        debug_assert!(n.is_power_of_two());
        let mut h = 1;
        while h < n {
            let stride = h * 2;
            let mut base = 0;
            while base < n {
                for i in base..base + h {
                    let a = v[i];
                    let b = v[i + h];
                    v[i] = a + b;
                    v[i + h] = a - b;
                }
                base += stride;
            }
            h = stride;
        }
    }

    /// `max_i |v[i]|`, NaN-ignoring exactly like sequential
    /// `f32::max` (a NaN element leaves the running max unchanged).
    pub fn absmax(v: &[f32]) -> f32 {
        let mut m = 0.0f32;
        for &x in v {
            m = m.max(x.abs());
        }
        m
    }

    /// `out[i] = quantize_unit(v[i] · qs)`.
    pub fn quantize_block(v: &[f32], qs: f32, out: &mut [u8]) {
        debug_assert_eq!(v.len(), out.len());
        for (o, &x) in out.iter_mut().zip(v) {
            *o = super::quantize_unit(x * qs);
        }
    }

    /// `out[i] = (q[i] as i8 as f32) / 127 · scale`.
    pub fn dequantize_block(q: &[u8], scale: f32, out: &mut [f32]) {
        debug_assert_eq!(q.len(), out.len());
        for (o, &b) in out.iter_mut().zip(q) {
            *o = (b as i8) as f32 / 127.0 * scale;
        }
    }

    /// `out[i] = src[i] · inv_sqrt · signs[i]` (quant8 decode tail).
    pub fn scaled_signed_mul(src: &[f32], signs: &[f32], inv_sqrt: f32, out: &mut [f32]) {
        debug_assert_eq!(src.len(), out.len());
        debug_assert_eq!(signs.len(), out.len());
        for i in 0..out.len() {
            out[i] = src[i] * inv_sqrt * signs[i];
        }
    }

    /// DGC momentum-correction scan:
    /// `u[i] = m·u[i] + delta[i]·scale; v[i] += u[i]`.
    pub fn dgc_scan(u: &mut [f32], v: &mut [f32], delta: &[f32], m: f32, scale: f32) {
        debug_assert_eq!(u.len(), delta.len());
        debug_assert_eq!(v.len(), delta.len());
        for i in 0..delta.len() {
            u[i] = m * u[i] + delta[i] * scale;
            v[i] += u[i];
        }
    }

    /// Append `src[idx[k]]` for each index (DGC top-k value gather).
    pub fn gather_extend(out: &mut Vec<f32>, src: &[f32], idx: &[u32]) {
        out.extend(idx.iter().map(|&i| src[i as usize]));
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    //! AVX2 twins of [`super::scalar`]. Every function is
    //! bit-identical to its scalar reference (module docs); tails
    //! shorter than one 8-lane vector delegate to the scalar code.
    //!
    //! Safety: every function in this module requires AVX2; callers
    //! dispatch through [`super::level`], which only selects these
    //! after `is_x86_feature_detected!("avx2")` succeeded.

    use super::scalar;
    use std::arch::x86_64::*;

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_row(out: &mut [f32], x: f32, w: &[f32]) {
        debug_assert_eq!(out.len(), w.len());
        let n = out.len();
        let xv = _mm256_set1_ps(x);
        let mut j = 0;
        while j + 8 <= n {
            let wv = _mm256_loadu_ps(w.as_ptr().add(j));
            let ov = _mm256_loadu_ps(out.as_ptr().add(j));
            let r = _mm256_add_ps(ov, _mm256_mul_ps(xv, wv));
            _mm256_storeu_ps(out.as_mut_ptr().add(j), r);
            j += 8;
        }
        scalar::axpy_row(&mut out[j..], x, &w[j..]);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn relu_mask_row(pre: &[f32], mask: &[f32], out: &mut [f32]) {
        debug_assert_eq!(pre.len(), out.len());
        debug_assert_eq!(mask.len(), out.len());
        let n = out.len();
        let zero = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let p = _mm256_loadu_ps(pre.as_ptr().add(j));
            let m = _mm256_loadu_ps(mask.as_ptr().add(j));
            let prod = _mm256_mul_ps(p, m);
            let gt = _mm256_cmp_ps::<_CMP_GT_OQ>(p, zero);
            _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_and_ps(prod, gt));
            j += 8;
        }
        scalar::relu_mask_row(&pre[j..], &mask[j..], &mut out[j..]);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn div_inplace(v: &mut [f32], z: f32) {
        let n = v.len();
        let zv = _mm256_set1_ps(z);
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(v.as_ptr().add(j));
            _mm256_storeu_ps(v.as_mut_ptr().add(j), _mm256_div_ps(x, zv));
            j += 8;
        }
        scalar::div_inplace(&mut v[j..], z);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_inplace(v: &mut [f32], a: f32) {
        let n = v.len();
        let av = _mm256_set1_ps(a);
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(v.as_ptr().add(j));
            _mm256_storeu_ps(v.as_mut_ptr().add(j), _mm256_mul_ps(x, av));
            j += 8;
        }
        scalar::scale_inplace(&mut v[j..], a);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_inplace(v: &mut [f32], s: &[f32]) {
        debug_assert_eq!(v.len(), s.len());
        let n = v.len();
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(v.as_ptr().add(j));
            let sv = _mm256_loadu_ps(s.as_ptr().add(j));
            _mm256_storeu_ps(v.as_mut_ptr().add(j), _mm256_mul_ps(x, sv));
            j += 8;
        }
        scalar::mul_inplace(&mut v[j..], &s[j..]);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn weighted_colsum_sub(w: &mut [f32], g: &[f32], av: &[f32], lr: f32) {
        let n = w.len();
        debug_assert_eq!(g.len(), av.len() * n);
        let lrv = _mm256_set1_ps(lr);
        let mut j = 0;
        while j + 8 <= n {
            let mut acc = _mm256_setzero_ps();
            for (t, &a) in av.iter().enumerate() {
                let gv = _mm256_loadu_ps(g.as_ptr().add(t * n + j));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(a), gv));
            }
            let wv = _mm256_loadu_ps(w.as_ptr().add(j));
            let r = _mm256_sub_ps(wv, _mm256_mul_ps(lrv, acc));
            _mm256_storeu_ps(w.as_mut_ptr().add(j), r);
            j += 8;
        }
        // Scalar tail: re-slice g by column range.
        for jj in j..n {
            let mut acc = 0.0f32;
            for (t, &a) in av.iter().enumerate() {
                acc += a * g[t * n + jj];
            }
            w[jj] -= lr * acc;
        }
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn colsum_sub(bias: &mut [f32], g: &[f32], lr: f32) {
        let n = bias.len();
        if n == 0 {
            return;
        }
        debug_assert_eq!(g.len() % n, 0);
        let rows = g.len() / n;
        let lrv = _mm256_set1_ps(lr);
        let mut j = 0;
        while j + 8 <= n {
            let mut acc = _mm256_setzero_ps();
            for t in 0..rows {
                acc = _mm256_add_ps(acc, _mm256_loadu_ps(g.as_ptr().add(t * n + j)));
            }
            let bv = _mm256_loadu_ps(bias.as_ptr().add(j));
            let r = _mm256_sub_ps(bv, _mm256_mul_ps(lrv, acc));
            _mm256_storeu_ps(bias.as_mut_ptr().add(j), r);
            j += 8;
        }
        for jj in j..n {
            let mut acc = 0.0f32;
            for t in 0..rows {
                acc += g[t * n + jj];
            }
            bias[jj] -= lr * acc;
        }
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn fwht(v: &mut [f32]) {
        let n = v.len();
        debug_assert!(n.is_power_of_two());
        if n < 16 {
            scalar::fwht(v);
            return;
        }
        // Scalar butterflies while the half-width is below one vector;
        // identical pairing and op order to the scalar reference.
        let mut h = 1;
        while h < 8 {
            let stride = h * 2;
            let mut base = 0;
            while base < n {
                for i in base..base + h {
                    let a = v[i];
                    let b = v[i + h];
                    v[i] = a + b;
                    v[i + h] = a - b;
                }
                base += stride;
            }
            h = stride;
        }
        // h ≥ 8: both butterfly operands are full 8-lane vectors.
        while h < n {
            let stride = h * 2;
            let mut base = 0;
            while base < n {
                let mut i = base;
                while i < base + h {
                    let a = _mm256_loadu_ps(v.as_ptr().add(i));
                    let b = _mm256_loadu_ps(v.as_ptr().add(i + h));
                    _mm256_storeu_ps(v.as_mut_ptr().add(i), _mm256_add_ps(a, b));
                    _mm256_storeu_ps(v.as_mut_ptr().add(i + h), _mm256_sub_ps(a, b));
                    i += 8;
                }
                base += stride;
            }
            h = stride;
        }
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn absmax(v: &[f32]) -> f32 {
        let n = v.len();
        let sign = _mm256_set1_ps(-0.0);
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_andnot_ps(sign, _mm256_loadu_ps(v.as_ptr().add(j)));
            // maxps(x, acc) keeps acc when x is NaN — the scalar
            // f32::max NaN-ignoring semantics.
            acc = _mm256_max_ps(x, acc);
            j += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut m = lanes.iter().fold(0.0f32, |a, &b| a.max(b));
        for &x in &v[j..] {
            m = m.max(x.abs());
        }
        m
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_block(v: &[f32], qs: f32, out: &mut [u8]) {
        debug_assert_eq!(v.len(), out.len());
        let n = v.len();
        let qsv = _mm256_set1_ps(qs);
        let magic = _mm256_set1_ps(super::ROUND_MAGIC);
        let hi = _mm256_set1_ps(127.0);
        let lo = _mm256_set1_ps(-127.0);
        let mut lanes = [0i32; 8];
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(v.as_ptr().add(j));
            let t = _mm256_mul_ps(x, qsv);
            let r = _mm256_sub_ps(_mm256_add_ps(t, magic), magic);
            // minps/maxps return the second operand on NaN — exactly
            // Rust's `f32::min`/`f32::max` with the operands in this
            // order, so non-finite inputs quantize identically.
            let c = _mm256_max_ps(_mm256_min_ps(r, hi), lo);
            let q = _mm256_cvtps_epi32(c);
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, q);
            for (k, &l) in lanes.iter().enumerate() {
                out[j + k] = l as u8;
            }
            j += 8;
        }
        scalar::quantize_block(&v[j..], qs, &mut out[j..]);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_block(q: &[u8], scale: f32, out: &mut [f32]) {
        debug_assert_eq!(q.len(), out.len());
        let n = out.len();
        let sv = _mm256_set1_ps(scale);
        let d127 = _mm256_set1_ps(127.0);
        let mut j = 0;
        while j + 8 <= n {
            let b = _mm_loadl_epi64(q.as_ptr().add(j) as *const __m128i);
            let w = _mm256_cvtepi8_epi32(b);
            let f = _mm256_cvtepi32_ps(w);
            let r = _mm256_mul_ps(_mm256_div_ps(f, d127), sv);
            _mm256_storeu_ps(out.as_mut_ptr().add(j), r);
            j += 8;
        }
        scalar::dequantize_block(&q[j..], scale, &mut out[j..]);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn scaled_signed_mul(src: &[f32], signs: &[f32], inv_sqrt: f32, out: &mut [f32]) {
        debug_assert_eq!(src.len(), out.len());
        debug_assert_eq!(signs.len(), out.len());
        let n = out.len();
        let iv = _mm256_set1_ps(inv_sqrt);
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(src.as_ptr().add(j));
            let s = _mm256_loadu_ps(signs.as_ptr().add(j));
            let r = _mm256_mul_ps(_mm256_mul_ps(x, iv), s);
            _mm256_storeu_ps(out.as_mut_ptr().add(j), r);
            j += 8;
        }
        scalar::scaled_signed_mul(&src[j..], &signs[j..], inv_sqrt, &mut out[j..]);
    }

    /// # Safety
    /// Requires AVX2 (guaranteed by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dgc_scan(u: &mut [f32], v: &mut [f32], delta: &[f32], m: f32, scale: f32) {
        debug_assert_eq!(u.len(), delta.len());
        debug_assert_eq!(v.len(), delta.len());
        let n = delta.len();
        let mv = _mm256_set1_ps(m);
        let sc = _mm256_set1_ps(scale);
        let mut j = 0;
        while j + 8 <= n {
            let uv = _mm256_loadu_ps(u.as_ptr().add(j));
            let dv = _mm256_loadu_ps(delta.as_ptr().add(j));
            let un = _mm256_add_ps(_mm256_mul_ps(mv, uv), _mm256_mul_ps(dv, sc));
            _mm256_storeu_ps(u.as_mut_ptr().add(j), un);
            let vv = _mm256_loadu_ps(v.as_ptr().add(j));
            _mm256_storeu_ps(v.as_mut_ptr().add(j), _mm256_add_ps(vv, un));
            j += 8;
        }
        scalar::dgc_scan(&mut u[j..], &mut v[j..], &delta[j..], m, scale);
    }

    /// # Safety
    /// Requires AVX2, every `idx` in-bounds for `src`, and
    /// `src.len() ≤ i32::MAX` (the dispatcher checks the length; the
    /// caller guarantees the indices, as in the scalar path).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_extend(out: &mut Vec<f32>, src: &[f32], idx: &[u32]) {
        let k = idx.len();
        out.reserve(k);
        let mut lanes = [0.0f32; 8];
        let mut j = 0;
        while j + 8 <= k {
            let iv = _mm256_loadu_si256(idx.as_ptr().add(j) as *const __m256i);
            let g = _mm256_i32gather_ps::<4>(src.as_ptr(), iv);
            _mm256_storeu_ps(lanes.as_mut_ptr(), g);
            out.extend_from_slice(&lanes);
            j += 8;
        }
        scalar::gather_extend(out, src, &idx[j..]);
    }
}

// Dispatch helper: with the feature compiled in, branch on the cached
// level (the AVX2 arm is only reachable after a successful probe —
// that is the safety argument for the `unsafe` call); without it, the
// scalar expression is the whole expansion and the AVX2 tokens are
// never name-resolved.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
macro_rules! dispatch {
    ($scalar:expr, $avx2:expr) => {
        match level() {
            SimdLevel::Avx2 => unsafe { $avx2 },
            SimdLevel::Scalar => $scalar,
        }
    };
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
macro_rules! dispatch {
    ($scalar:expr, $avx2:expr) => {
        $scalar
    };
}

/// `out[j] += x · w[j]` — the GEMM/rank-1 inner row op.
#[inline]
pub fn axpy_row(out: &mut [f32], x: f32, w: &[f32]) {
    dispatch!(scalar::axpy_row(out, x, w), avx2::axpy_row(out, x, w))
}

/// Fused ReLU + unit-mask row: `out[j] = pre[j] > 0 ? pre[j]·mask[j] : 0`.
#[inline]
pub fn relu_mask_row(pre: &[f32], mask: &[f32], out: &mut [f32]) {
    dispatch!(
        scalar::relu_mask_row(pre, mask, out),
        avx2::relu_mask_row(pre, mask, out)
    )
}

/// `v[i] /= z` (softmax normalization; stays a true division).
#[inline]
pub fn div_inplace(v: &mut [f32], z: f32) {
    dispatch!(scalar::div_inplace(v, z), avx2::div_inplace(v, z))
}

/// `v[i] *= a`.
#[inline]
pub fn scale_inplace(v: &mut [f32], a: f32) {
    dispatch!(scalar::scale_inplace(v, a), avx2::scale_inplace(v, a))
}

/// `v[i] *= s[i]`.
#[inline]
pub fn mul_inplace(v: &mut [f32], s: &[f32]) {
    dispatch!(scalar::mul_inplace(v, s), avx2::mul_inplace(v, s))
}

/// `w[j] -= lr · Σ_t av[t]·g[t·n + j]` (blocked SGD weight update; the
/// per-column sum keeps `t` ascending on both paths).
#[inline]
pub fn weighted_colsum_sub(w: &mut [f32], g: &[f32], av: &[f32], lr: f32) {
    dispatch!(
        scalar::weighted_colsum_sub(w, g, av, lr),
        avx2::weighted_colsum_sub(w, g, av, lr)
    )
}

/// `bias[j] -= lr · Σ_t g[t·n + j]` (blocked SGD bias update).
#[inline]
pub fn colsum_sub(bias: &mut [f32], g: &[f32], lr: f32) {
    dispatch!(scalar::colsum_sub(bias, g, lr), avx2::colsum_sub(bias, g, lr))
}

/// In-place unnormalized FWHT (identical butterfly order on both
/// paths; callers apply the `1/√B` normalization).
#[inline]
pub fn fwht(v: &mut [f32]) {
    dispatch!(scalar::fwht(v), avx2::fwht(v))
}

/// `max_i |v[i]|`, NaN-ignoring (quant8 scale scan).
#[inline]
pub fn absmax(v: &[f32]) -> f32 {
    dispatch!(scalar::absmax(v), avx2::absmax(v))
}

/// Quantize a rotated block: `out[i] = quantize_unit(v[i]·qs)`.
#[inline]
pub fn quantize_block(v: &[f32], qs: f32, out: &mut [u8]) {
    dispatch!(
        scalar::quantize_block(v, qs, out),
        avx2::quantize_block(v, qs, out)
    )
}

/// Dequantize a block: `out[i] = (q[i] as i8 as f32)/127 · scale`.
#[inline]
pub fn dequantize_block(q: &[u8], scale: f32, out: &mut [f32]) {
    dispatch!(
        scalar::dequantize_block(q, scale, out),
        avx2::dequantize_block(q, scale, out)
    )
}

/// `out[i] = src[i] · inv_sqrt · signs[i]` (quant8 decode tail).
#[inline]
pub fn scaled_signed_mul(src: &[f32], signs: &[f32], inv_sqrt: f32, out: &mut [f32]) {
    dispatch!(
        scalar::scaled_signed_mul(src, signs, inv_sqrt, out),
        avx2::scaled_signed_mul(src, signs, inv_sqrt, out)
    )
}

/// DGC momentum scan: `u = m·u + delta·scale; v += u` (elementwise, no
/// reassociation — bit-identical on both paths).
#[inline]
pub fn dgc_scan(u: &mut [f32], v: &mut [f32], delta: &[f32], m: f32, scale: f32) {
    dispatch!(
        scalar::dgc_scan(u, v, delta, m, scale),
        avx2::dgc_scan(u, v, delta, m, scale)
    )
}

/// Append `src[idx[k]]` for each index (DGC value gather; every index
/// must be in-bounds for `src`). Sources larger than `i32::MAX`
/// elements always take the scalar path (AVX2 gathers index with i32).
#[inline]
pub fn gather_extend(out: &mut Vec<f32>, src: &[f32], idx: &[u32]) {
    debug_assert!(idx.iter().all(|&i| (i as usize) < src.len()));
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level() == SimdLevel::Avx2 && src.len() <= i32::MAX as usize {
        unsafe { avx2::gather_extend(out, src, idx) };
        return;
    }
    scalar::gather_extend(out, src, idx);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn init_is_idempotent_and_names_the_level() {
        let a = init();
        let b = level();
        assert_eq!(a, b);
        match a {
            SimdLevel::Avx2 => assert_eq!(active_name(), "avx2"),
            SimdLevel::Scalar => assert_eq!(active_name(), "scalar"),
        }
        // cpu_features never lies about the dispatch prerequisites.
        if a == SimdLevel::Avx2 {
            assert!(cpu_features().contains(&"avx2"));
        }
    }

    #[test]
    fn quantize_unit_rounds_ties_to_even_and_clamps() {
        assert_eq!(quantize_unit(0.0) as i8, 0);
        assert_eq!(quantize_unit(1.4) as i8, 1);
        assert_eq!(quantize_unit(1.5) as i8, 2);
        assert_eq!(quantize_unit(2.5) as i8, 2, "ties to even");
        assert_eq!(quantize_unit(-2.5) as i8, -2, "ties to even");
        assert_eq!(quantize_unit(-1.6) as i8, -2);
        assert_eq!(quantize_unit(127.0) as i8, 127);
        assert_eq!(quantize_unit(-127.0) as i8, -127);
        assert_eq!(quantize_unit(f32::INFINITY) as i8, 127);
        assert_eq!(quantize_unit(f32::NEG_INFINITY) as i8, -127);
    }

    #[test]
    fn dispatched_ops_match_scalar_bitwise() {
        // Trivially true without AVX2; the real check runs under
        // `--features simd` on an AVX2 machine (and exhaustively in
        // rust/tests/simd_conformance.rs).
        for n in [0usize, 1, 7, 8, 9, 64, 100] {
            let w = gauss(n, 1);
            let mut a = gauss(n, 2);
            let mut b = a.clone();
            axpy_row(&mut a, 0.37, &w);
            scalar::axpy_row(&mut b, 0.37, &w);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "n={n}"
            );
        }
    }

    #[test]
    fn absmax_ignores_nan_like_sequential_max() {
        let mut v = gauss(33, 3);
        v[7] = f32::NAN;
        v[20] = f32::NAN;
        let got = absmax(&v);
        let want = scalar::absmax(&v);
        assert_eq!(got.to_bits(), want.to_bits());
        assert!(got.is_finite());
        assert_eq!(absmax(&[]), 0.0);
        assert_eq!(absmax(&[f32::NAN; 9]), 0.0);
    }

    #[test]
    fn gather_matches_indexing() {
        let src = gauss(500, 4);
        let idx: Vec<u32> = (0..137).map(|i| (i * 3) % 500).collect();
        let mut out = Vec::new();
        gather_extend(&mut out, &src, &idx);
        let want: Vec<f32> = idx.iter().map(|&i| src[i as usize]).collect();
        assert_eq!(out, want);
    }
}
