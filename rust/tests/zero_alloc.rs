//! Zero-allocation proof for the hot paths: with a warmed
//! [`Workspace`], a full `train_epoch` and the plan-based
//! pack/unpack/mask perform **no heap allocations** — counted by a
//! real `GlobalAlloc` wrapper, not inferred.
//!
//! This test lives alone in its own integration-test binary because
//! the counting allocator is process-global: nothing else may allocate
//! while the counter is armed.

use afd::model::packing::PackPlan;
use afd::model::submodel::SubModel;
use afd::runtime::native::{mlp_spec, NativeMlp};
use afd::runtime::{BatchInput, EpochData, ModelRuntime};
use afd::tensor::kernels::Workspace;
use afd::util::alloc_count::{self, CountingAllocator};
use afd::util::rng::Pcg64;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

#[test]
fn train_epoch_and_plan_packing_allocate_nothing_after_warmup() {
    // ---- setup (allocates freely) -----------------------------------
    let spec = mlp_spec("z", 24, 16, 6, 8, 3, 0.1);
    let mlp = NativeMlp::new(spec.clone());
    let mut params = mlp.init_params(1);
    let mut rng = Pcg64::new(2);
    let n_samples = spec.num_batches * spec.batch_size;
    let xs: Vec<f32> = (0..n_samples * 24).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ys: Vec<i32> = (0..n_samples).map(|_| rng.below(6) as i32).collect();
    let data = EpochData {
        xs: BatchInput::F32(xs),
        ys,
    };
    let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2, 3, 5, 8, 9, 11, 14, 15]]);
    let masks = sm.masks_f32();
    let mut ws = Workspace::new();

    // Warm-up: first call may allocate workspace buffers.
    mlp.train_epoch_in(&mut ws, &mut params, &masks, &data, 0.1)
        .unwrap();

    // ---- train_epoch under the counter ------------------------------
    alloc_count::arm();
    mlp.train_epoch_in(&mut ws, &mut params, &masks, &data, 0.1)
        .unwrap();
    let train_allocs = alloc_count::disarm();
    assert_eq!(
        train_allocs, 0,
        "train_epoch made {train_allocs} allocations after warm-up"
    );

    // ---- plan-based pack/unpack/mask under the counter --------------
    let plan = PackPlan::build(&spec, &sm);
    let mut packed = Vec::new();
    let mut full = params.clone();
    let mut cmask = vec![false; spec.num_params];
    plan.pack_into(&params, &mut packed); // warm the output buffer

    alloc_count::arm();
    plan.pack_into(&params, &mut packed);
    plan.unpack_from(&packed, &mut full);
    plan.mark_coord_mask(&mut cmask);
    let pack_allocs = alloc_count::disarm();
    assert_eq!(
        pack_allocs, 0,
        "plan-based packing made {pack_allocs} allocations after warm-up"
    );

    // Sanity: the counter itself works (an allocation is observed).
    alloc_count::arm();
    let v: Vec<u8> = Vec::with_capacity(1024);
    std::hint::black_box(&v);
    let observed = alloc_count::disarm();
    drop(v);
    assert!(observed >= 1, "counter failed to observe an allocation");
}
