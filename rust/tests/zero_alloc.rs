//! Zero-allocation proof for the hot paths: with a warmed
//! [`Workspace`], a full `train_epoch`, the plan-based
//! pack/unpack/mask — and the **entire client round** (epoch assembly
//! → pack → encode → decode → train → DGC compress/decode → batched
//! aggregate) — and a warm telemetry snapshot encode perform **no
//! heap allocations**, counted by a real `GlobalAlloc` wrapper, not
//! inferred.
//!
//! These tests live alone in their own integration-test binary because
//! the counting allocator is process-global: nothing else may allocate
//! while the counter is armed (`cargo test` runs tests in one binary
//! on multiple threads — each test arms the counter only around its
//! own quiesced region, so they must not run concurrently; the
//! `serial` mutex below enforces that).

use std::sync::{Arc, Mutex};

use afd::aggregation::{AddOp, ShardedFedAvg};
use afd::clients::{Population, PopulationConfig};
use afd::compression::dgc::{DgcConfig, DgcState};
use afd::compression::quant::HadamardQuant8;
use afd::compression::{sparse, DenseCodec, Encoded};
use afd::data::{ClientDataset, FederatedDataset, Samples};
use afd::model::packing::PackPlan;
use afd::model::submodel::SubModel;
use afd::runtime::native::{mlp_spec, NativeMlp};
use afd::runtime::{BatchInput, EpochData, ModelRuntime};
use afd::tensor::kernels::Workspace;
use afd::util::alloc_count::{self, CountingAllocator};
use afd::util::pool::LazyPool;
use afd::util::rng::Pcg64;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// The counting allocator is process-global; serialize the tests.
static SERIAL: Mutex<()> = Mutex::new(());

/// Transport contract: framing a full round's conversation into warm
/// sinks — offer, model, update, round-close — and parsing every frame
/// back (header, CRC, payload grammar, bitmap compare) performs zero
/// heap allocations. Frames extend the PR 4 zero-alloc contract
/// instead of breaking it.
#[test]
fn frame_encode_parse_allocates_nothing_after_warmup() {
    let _guard = SERIAL.lock().unwrap();
    use afd::transport::frame;
    // Tracing active: `end_frame`/`parse_frame` now tick frame
    // counters and byte histograms, which must stay alloc-free too.
    afd::obs::set_enabled(true);
    afd::obs::register_thread();

    let sm = SubModel::from_keep(vec![(0..64).map(|i| i % 3 != 0).collect()]);
    let payload: Vec<u8> = (0..512).map(|i| i as u8).collect();
    let mut offer = Vec::new();
    let mut model = Vec::new();
    let mut upd = Vec::new();
    let mut close = Vec::new();

    let mut round = |offer: &mut Vec<u8>,
                     model: &mut Vec<u8>,
                     upd: &mut Vec<u8>,
                     close: &mut Vec<u8>| {
        offer.clear();
        frame::encode_round_offer(offer, 3, 7, 0xfeed, 0.1, f64::NAN, &sm);
        model.clear();
        frame::encode_model_down(model, 3, 7, 1, &payload);
        upd.clear();
        let base = frame::begin_update_up(upd, 3, 7, 40, 0.25, frame::UPDATE_DGC);
        upd.extend_from_slice(&payload[..100]);
        frame::end_frame(upd, base);
        close.clear();
        frame::encode_round_close(close, true, 3, 7);

        let (v, _) = frame::parse_frame(offer).unwrap();
        let o = frame::parse_round_offer(&v).unwrap();
        assert!(o.matches_submodel(&sm));
        let (v, _) = frame::parse_frame(model).unwrap();
        let m = frame::parse_model_down(&v).unwrap();
        assert_eq!(m.payload.len(), payload.len());
        let (v, _) = frame::parse_frame(upd).unwrap();
        let u = frame::parse_update_up(&v).unwrap();
        assert_eq!(u.payload.len(), 100);
        let (v, _) = frame::parse_frame(close).unwrap();
        frame::parse_round_close(&v).unwrap();
    };

    // Warm-up sizes the sinks; the armed pass must not touch the heap.
    round(&mut offer, &mut model, &mut upd, &mut close);
    alloc_count::arm();
    round(&mut offer, &mut model, &mut upd, &mut close);
    let allocs = alloc_count::disarm();
    afd::obs::set_enabled(false);
    assert_eq!(allocs, 0, "framing a warm round made {allocs} allocations");
}

/// Distributed-telemetry contract: encoding a warm incremental
/// telemetry snapshot — new span-ring records, counter deltas, stage
/// histogram deltas, framed and CRC-sealed — performs zero heap
/// allocations. The shipper's cursor tables are preallocated at
/// construction and the frame sink is sized by the warm-up passes, so
/// a remote client can ship telemetry every round without breaking
/// the PR 4 zero-alloc contract.
#[test]
fn telemetry_snapshot_encode_allocates_nothing_after_warmup() {
    let _guard = SERIAL.lock().unwrap();
    use afd::obs::remote::Shipper;
    use afd::obs::Stage;
    use afd::transport::frame;
    afd::obs::set_enabled(true);
    afd::obs::register_thread();

    let mut shipper = Shipper::new();
    let mut out: Vec<u8> = Vec::with_capacity(64 * 1024);

    let record_some = || {
        for i in 0..8u64 {
            let _g = afd::obs::span_ab(Stage::CodecEncode, i, i + 1);
        }
        afd::obs::mark(Stage::FaultMark, 1, 2);
        afd::obs::metrics::ROUNDS_COMPLETED.incr();
        afd::obs::metrics::BYTES_UP_WIRE.add(128);
    };

    // Warm-up: the first encode sizes the per-ring cursor table and
    // the frame sink, the second settles them.
    record_some();
    shipper.encode_into(&mut out, 1);
    record_some();
    out.clear();
    shipper.encode_into(&mut out, 2);

    // Armed: fresh spans and counter deltas through warm buffers.
    record_some();
    out.clear();
    alloc_count::arm();
    shipper.encode_into(&mut out, 3);
    let allocs = alloc_count::disarm();
    let was_live = afd::obs::enabled();
    afd::obs::set_enabled(false);
    assert_eq!(
        allocs, 0,
        "a warm telemetry snapshot encode made {allocs} allocations"
    );

    // The armed pass produced a real, parseable frame carrying the
    // fresh records (when the trace feature is compiled in).
    let (view, used) = frame::parse_frame(&out).unwrap();
    assert_eq!(used, out.len());
    let msg = frame::parse_telemetry(&view).unwrap();
    assert_eq!(msg.round, 3);
    if was_live {
        assert!(
            msg.threads.iter().any(|t| !t.spans.is_empty()),
            "armed snapshot shipped no spans despite live tracing"
        );
    }
}

#[test]
fn train_epoch_and_plan_packing_allocate_nothing_after_warmup() {
    let _guard = SERIAL.lock().unwrap();
    // ---- setup (allocates freely) -----------------------------------
    let spec = mlp_spec("z", 24, 16, 6, 8, 3, 0.1);
    let mlp = NativeMlp::new(spec.clone());
    let mut params = mlp.init_params(1);
    let mut rng = Pcg64::new(2);
    let n_samples = spec.num_batches * spec.batch_size;
    let xs: Vec<f32> = (0..n_samples * 24).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ys: Vec<i32> = (0..n_samples).map(|_| rng.below(6) as i32).collect();
    let data = EpochData {
        xs: BatchInput::F32(xs),
        ys,
    };
    let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2, 3, 5, 8, 9, 11, 14, 15]]);
    let masks = sm.masks_f32();
    let mut ws = Workspace::new();

    // Warm-up: first call may allocate workspace buffers.
    mlp.train_epoch_in(&mut ws, &mut params, &masks, &data, 0.1)
        .unwrap();

    // ---- train_epoch under the counter ------------------------------
    alloc_count::arm();
    mlp.train_epoch_in(&mut ws, &mut params, &masks, &data, 0.1)
        .unwrap();
    let train_allocs = alloc_count::disarm();
    assert_eq!(
        train_allocs, 0,
        "train_epoch made {train_allocs} allocations after warm-up"
    );

    // ---- plan-based pack/unpack/mask under the counter --------------
    let plan = PackPlan::build(&spec, &sm);
    let mut packed = Vec::new();
    let mut full = params.clone();
    let mut cmask = vec![false; spec.num_params];
    plan.pack_into(&params, &mut packed); // warm the output buffer

    alloc_count::arm();
    plan.pack_into(&params, &mut packed);
    plan.unpack_from(&packed, &mut full);
    plan.mark_coord_mask(&mut cmask);
    let pack_allocs = alloc_count::disarm();
    assert_eq!(
        pack_allocs, 0,
        "plan-based packing made {pack_allocs} allocations after warm-up"
    );

    // Sanity: the counter itself works (an allocation is observed).
    alloc_count::arm();
    let v: Vec<u8> = Vec::with_capacity(1024);
    std::hint::black_box(&v);
    let observed = alloc_count::disarm();
    drop(v);
    assert!(observed >= 1, "counter failed to observe an allocation");
}

/// The tentpole contract: one whole warm client round — epoch
/// assembly, downlink pack → quant8 encode → decode → unpack, local
/// training, DGC compress → sparse decode → reconstruction, and the
/// batched FedAvg aggregate (single shard ⇒ inline, no pool) — makes
/// zero heap allocations. Every buffer is drawn from the Workspace
/// arena's f32/byte/u32/bool pools or from per-client recycled state,
/// mirroring exactly what `run_client_round` + the engine's batched
/// aggregation do per round.
///
/// Tracing is **enabled** for the armed pass: the span recorder's
/// per-thread ring, the stage histograms and the frame counters all
/// run live, extending the zero-alloc contract to the observability
/// layer (its ring is preallocated at `register_thread`).
#[test]
fn full_client_round_pipeline_allocates_nothing_after_warmup() {
    let _guard = SERIAL.lock().unwrap();
    afd::obs::set_enabled(true);
    afd::obs::register_thread();
    // ---- setup (allocates freely) -----------------------------------
    let (d, h, c) = (24usize, 16usize, 6usize);
    let spec = mlp_spec("round", d, h, c, 8, 3, 0.1);
    let n = spec.num_params;
    let mlp = NativeMlp::new(spec.clone());
    let mut global = mlp.init_params(1);

    // A client dataset large enough for one epoch without cycling.
    let mut rng = Pcg64::new(2);
    let samples = 30usize;
    let xs: Vec<f32> = (0..samples * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ys: Vec<i32> = (0..samples).map(|_| rng.below(c as u64) as i32).collect();
    let dataset = ClientDataset {
        xs: Samples::F32(xs),
        ys,
        per_sample: d,
    };

    let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2, 3, 5, 8, 9, 11, 14, 15]]);
    let plan = PackPlan::build(&spec, &sm);
    let codec = HadamardQuant8::default();
    let mut dgc_state = DgcState::new(DgcConfig::default());
    // Single shard: adds/finalize run inline on the caller thread (the
    // fan-out's per-dispatch control structures are the one part of a
    // round that inherently allocates; satellite-1's batching bounds
    // that to one dispatch per round).
    let mut agg = ShardedFedAvg::new(n, 1, Arc::new(LazyPool::new(1)));
    let mut agg_out: Vec<f32> = Vec::new();

    let mut ws = Workspace::new();
    let mut client_rng = Pcg64::with_stream(3, 1);
    let mut order: Vec<u32> = Vec::new();
    let mut data = EpochData {
        xs: BatchInput::F32(Vec::new()),
        ys: Vec::new(),
    };

    // Generous pre-reserve for the byte/u32 sinks so per-round wire
    // size jitter (varint index coding) can't force a warm realloc.
    let mut byte_bufs = Vec::new();
    for _ in 0..3 {
        let mut b = ws.take_bytes();
        b.reserve(4 * n + 1024);
        byte_bufs.push(b);
    }
    for b in byte_bufs {
        ws.give_bytes(b);
    }
    let mut u = ws.take_u32();
    u.reserve(n);
    ws.give_u32(u);

    let mut round = |ws: &mut Workspace,
                     client_rng: &mut Pcg64,
                     order: &mut Vec<u32>,
                     data: &mut EpochData,
                     dgc_state: &mut DgcState,
                     agg: &mut ShardedFedAvg,
                     global: &mut Vec<f32>,
                     agg_out: &mut Vec<f32>| {
        // Epoch assembly into recycled buffers.
        dataset.epoch_data_into(&spec, client_rng, order, data);
        // Downlink: pack → encode → decode → unpack.
        let mut packed = ws.take_uncleared(plan.packed_len());
        plan.pack_into(global, &mut packed);
        let mut enc = Encoded {
            bytes: ws.take_bytes(),
        };
        codec.encode_into(&packed, 7, ws, &mut enc);
        let mut decoded = ws.take_uncleared(plan.packed_len());
        codec.decode_into(&enc, 7, ws, &mut decoded);
        ws.give_bytes(enc.bytes);
        let mut start = ws.take_uncleared(n);
        start.copy_from_slice(global);
        plan.unpack_from(&decoded, &mut start);
        ws.give(decoded);
        // Local training.
        let mut model = ws.take_uncleared(n);
        model.copy_from_slice(&start);
        mlp.train_epoch_in(ws, &mut model, sm.masks_f32(), data, 0.1)
            .unwrap();
        // Uplink: DGC compress → sparse decode → reconstruction.
        let mut coord_mask = ws.take_bool(n);
        plan.mark_coord_mask(&mut coord_mask);
        let mut delta = ws.take_uncleared(n);
        afd::tensor::sub(&model, &start, &mut delta);
        let mut scratch = ws.take_bytes();
        let mut msg = ws.take_bytes();
        dgc_state.compress_into(&delta, &mut scratch, &mut msg);
        ws.give(delta);
        ws.give_bytes(scratch);
        let mut idx = ws.take_u32();
        let mut vals = ws.take_uncleared(0);
        sparse::decode_sparse_into(&msg, &mut idx, &mut vals);
        ws.give_bytes(msg);
        let mut recon = ws.take_uncleared(n);
        recon.copy_from_slice(&start);
        for (&i, &v) in idx.iter().zip(vals.iter()) {
            if v != 0.0 {
                recon[i as usize] += v;
                coord_mask[i as usize] = true;
            }
        }
        ws.give_u32(idx);
        ws.give(vals);
        // Aggregate: the round's adds + finalize in one batch.
        let ops = [AddOp::Masked {
            values: &recon,
            coord_mask: &coord_mask,
            n_c: 20.0,
        }];
        agg.aggregate_batch(&ops, global, agg_out);
        std::mem::swap(global, agg_out);
        ws.give(packed);
        ws.give(start);
        ws.give(model);
        ws.give(recon);
        ws.give_bool(coord_mask);
    };

    // Two warm-up rounds (the first sizes the DGC accumulators and the
    // arena; the second settles sink-to-call-site pairing).
    for _ in 0..2 {
        round(
            &mut ws,
            &mut client_rng,
            &mut order,
            &mut data,
            &mut dgc_state,
            &mut agg,
            &mut global,
            &mut agg_out,
        );
    }

    let train_spans_before = afd::obs::metrics::STAGE_NS[afd::obs::Stage::Train as usize].count();
    alloc_count::arm();
    round(
        &mut ws,
        &mut client_rng,
        &mut order,
        &mut data,
        &mut dgc_state,
        &mut agg,
        &mut global,
        &mut agg_out,
    );
    let allocs = alloc_count::disarm();
    let tracing_was_live = afd::obs::enabled();
    afd::obs::set_enabled(false);
    assert_eq!(
        allocs, 0,
        "a full warm client round made {allocs} heap allocations (tracing on)"
    );
    // With the trace feature compiled in, the armed pass really did
    // record spans — the zero-alloc result covers live tracing, not a
    // disabled recorder.
    if tracing_was_live {
        let after = afd::obs::metrics::STAGE_NS[afd::obs::Stage::Train as usize].count();
        assert!(
            after > train_spans_before,
            "tracing was enabled but the armed round recorded no train span"
        );
    }

    // The pipeline still computes something sensible.
    assert!(global.iter().all(|v| v.is_finite()));
}

/// Population-store contract: a warm sample → rehydrate → train →
/// evict cycle through the [`Population`] + `ResidualStore` makes zero
/// heap allocations. Every cycle forces the full paging machinery —
/// the 1-byte budget evicts (spills) both clients at `end_round`, so
/// the armed pass rebuilds each client's shell from the free pools,
/// rehydrates its RNG/participations/DGC residuals from the spill
/// file, assembles an epoch into recycled buffers, trains, and spills
/// again.
#[test]
fn population_evict_rehydrate_train_cycle_allocates_nothing_after_warmup() {
    let _guard = SERIAL.lock().unwrap();
    // ---- setup (allocates freely) -----------------------------------
    let (d, h, c) = (24usize, 16usize, 6usize);
    let spec = mlp_spec("pop", d, h, c, 8, 3, 0.1);
    let n = spec.num_params;
    let mlp = NativeMlp::new(spec.clone());
    let global = mlp.init_params(1);

    let mut rng = Pcg64::new(9);
    let mut make_client = |samples: usize| {
        let xs: Vec<f32> = (0..samples * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let ys: Vec<i32> = (0..samples).map(|_| rng.below(c as u64) as i32).collect();
        ClientDataset {
            xs: Samples::F32(xs),
            ys,
            per_sample: d,
        }
    };
    let dataset = Arc::new(FederatedDataset {
        clients: vec![make_client(30), make_client(26)],
        test: make_client(8),
    });
    // A 1-byte budget: `end_round` always evicts every resident, so
    // every materialization after warm-up is a spill rehydration.
    let mut pop = Population::eager(
        dataset,
        DgcConfig::default(),
        7,
        &PopulationConfig {
            lazy: false,
            store_budget_bytes: 1,
            spill_dir: String::new(),
        },
    );

    let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2, 3, 5, 8, 9, 11, 14, 15]]);
    let mut ws = Workspace::new();
    let mut order: Vec<u32> = Vec::new();

    let mut cycle = |pop: &mut Population, ws: &mut Workspace, order: &mut Vec<u32>| {
        for client in 0..2usize {
            // Sample: materialize (rehydrating from spill when a record
            // exists) and run the engine's dispatch-time sequence.
            pop.client(client).participations += 1;
            let mut data = pop.client(client).take_epoch_buf();
            pop.assemble_epoch(client, &spec, order, &mut data);
            let mut dgc = pop.client(client).take_dgc();
            // Train + DGC compress so the spilled residuals are live.
            let mut model = ws.take_uncleared(n);
            model.copy_from_slice(&global);
            mlp.train_epoch_in(ws, &mut model, sm.masks_f32(), &data, 0.1)
                .unwrap();
            let mut delta = ws.take_uncleared(n);
            afd::tensor::sub(&model, &global, &mut delta);
            let mut scratch = ws.take_bytes();
            let mut msg = ws.take_bytes();
            dgc.compress_into(&delta, &mut scratch, &mut msg);
            ws.give(delta);
            ws.give(model);
            ws.give_bytes(scratch);
            ws.give_bytes(msg);
            let st = pop.client(client);
            st.put_dgc(dgc);
            st.put_epoch_buf(data);
        }
        // Round boundary: both clients evicted and spilled.
        pop.end_round();
    };

    // Two warm-ups: the first creates the spill file/slots and sizes
    // the scratch and pools, the second settles capacities.
    cycle(&mut pop, &mut ws, &mut order);
    cycle(&mut pop, &mut ws, &mut order);
    assert_eq!(pop.store().resident_len(), 0, "budget must evict everyone");
    assert_eq!(pop.store().spilled_len(), 2);

    alloc_count::arm();
    cycle(&mut pop, &mut ws, &mut order);
    let allocs = alloc_count::disarm();
    assert_eq!(
        allocs, 0,
        "a warm sample→rehydrate→train→evict cycle made {allocs} allocations"
    );
}
