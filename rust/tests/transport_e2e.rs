//! Transport end-to-end contracts:
//!
//! 1. **Base-params independence** — a client's update frame is a pure
//!    function of the wire (and its codec state), not of its
//!    off-sub-model parameter values. This is the invariant that lets
//!    a remote process (zeros base) reproduce the loopback path
//!    (global base) bit-for-bit.
//! 2. **TCP ≡ loopback** — a fixed-seed experiment over real sockets
//!    (in-process client threads running the actual `afd client`
//!    loop) produces byte-identical records and an identical final
//!    model hash to the loopback transport, for every scheduler
//!    policy. The transport never changes results, only where they
//!    run.

use std::sync::Arc;

use afd::compression::dgc::{DgcConfig, DgcState};
use afd::compression::quant::HadamardQuant8;
use afd::compression::DenseCodec;
use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::metrics::RoundRecord;
use afd::model::packing::PackPlan;
use afd::model::submodel::SubModel;
use afd::runtime::native::{mlp_from_config, mlp_spec, NativeMlp};
use afd::runtime::{BatchInput, EpochData};
use afd::tensor::kernels::Workspace;
use afd::transport::tcp::{run_client_loop, ClientOptions, TcpServer};
use afd::transport::{client_execute, ClientEnv, Transport};
use afd::util::model_hash;
use afd::util::rng::Pcg64;

fn assert_records_equal(a: &RoundRecord, b: &RoundRecord, what: &str) {
    assert_eq!(a.round, b.round, "{what}");
    assert_eq!(a.round_s.to_bits(), b.round_s.to_bits(), "{what} round {}", a.round);
    assert_eq!(a.cum_s.to_bits(), b.cum_s.to_bits(), "{what} round {}", a.round);
    assert_eq!(
        a.train_loss.to_bits(),
        b.train_loss.to_bits(),
        "{what} round {}",
        a.round
    );
    assert_eq!(
        a.eval_acc.map(f64::to_bits),
        b.eval_acc.map(f64::to_bits),
        "{what} round {}",
        a.round
    );
    assert_eq!(a.down_bytes, b.down_bytes, "{what} round {}", a.round);
    assert_eq!(a.up_bytes, b.up_bytes, "{what} round {}", a.round);
    assert_eq!(
        a.down_payload_bytes, b.down_payload_bytes,
        "{what} round {}",
        a.round
    );
    assert_eq!(
        a.up_payload_bytes, b.up_payload_bytes,
        "{what} round {}",
        a.round
    );
    assert_eq!(a.arrived, b.arrived, "{what} round {}", a.round);
    assert_eq!(a.cut, b.cut, "{what} round {}", a.round);
    assert_eq!(a.dropped, b.dropped, "{what} round {}", a.round);
    assert_eq!(a.lost, b.lost, "{what} round {}", a.round);
}

#[test]
fn client_base_params_do_not_affect_update() {
    let spec = mlp_spec("t", 12, 8, 4, 4, 2, 0.1);
    let mlp = NativeMlp::new(spec.clone());
    let global = mlp.init_params(3);
    let zeros = vec![0.0f32; spec.num_params];
    let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2, 3, 5, 6]]);
    let plan = PackPlan::build(&spec, &sm);
    let codec = HadamardQuant8::default();

    // One fixed epoch (both executions must see identical data).
    let mut rng = Pcg64::new(5);
    let ns = spec.samples_per_round();
    let xs: Vec<f32> = (0..ns * 12).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ys: Vec<i32> = (0..ns).map(|_| rng.below(4) as i32).collect();
    let data = EpochData {
        xs: BatchInput::F32(xs),
        ys,
    };

    // The downlink payload the server would ship.
    let mut packed = Vec::new();
    plan.pack_into(&global, &mut packed);
    let enc = codec.encode(&packed, 42);

    let mut ws = Workspace::new();
    for dgc_on in [true, false] {
        let mut d1 = DgcState::new(DgcConfig::default());
        let mut d2 = DgcState::new(DgcConfig::default());
        let mut r1 = Vec::new();
        let mut r2 = Vec::new();
        {
            let mut env = ClientEnv {
                spec: &spec,
                runtime: &mlp,
                codec: &codec,
                base_params: &global,
                data: &data,
                dgc: dgc_on.then_some(&mut d1),
                submodel: &sm,
                plan: &plan,
                num_samples: ns as u32,
                ws: &mut ws,
            };
            client_execute(1, 0, 42, 0.1, &enc.bytes, &mut env, &mut r1).unwrap();
        }
        {
            let mut env = ClientEnv {
                spec: &spec,
                runtime: &mlp,
                codec: &codec,
                base_params: &zeros,
                data: &data,
                dgc: dgc_on.then_some(&mut d2),
                submodel: &sm,
                plan: &plan,
                num_samples: ns as u32,
                ws: &mut ws,
            };
            client_execute(1, 0, 42, 0.1, &enc.bytes, &mut env, &mut r2).unwrap();
        }
        assert_eq!(r1, r2, "dgc={dgc_on}: update frames must be byte-identical");
        assert!(!r1.is_empty());
    }
}

fn run_loopback(cfg: &ExperimentConfig) -> (Vec<RoundRecord>, u64) {
    let mut exp = Experiment::build(cfg).unwrap();
    let mut records = Vec::new();
    for round in 1..=cfg.rounds {
        records.push(exp.step(round).unwrap());
    }
    (records, model_hash(&exp.global))
}

fn run_tcp(cfg: &ExperimentConfig, conns: usize) -> (Vec<RoundRecord>, u64) {
    let (_, spec) = mlp_from_config(cfg);
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handles: Vec<_> = (0..conns)
        .map(|_| {
            let a = addr.clone();
            let opts = ClientOptions {
                connect_retry_s: 10.0,
                ..ClientOptions::default()
            };
            std::thread::spawn(move || run_client_loop(&a, &opts))
        })
        .collect();
    let transport = server
        .accept_clients(
            conns,
            &cfg.to_json().to_string_compact(),
            spec.layout_fingerprint(),
            &cfg.transport,
        )
        .unwrap();
    let transport: Arc<dyn Transport> = Arc::new(transport);
    let mut exp = Experiment::build_with_transport(cfg, Arc::clone(&transport)).unwrap();
    let mut records = Vec::new();
    for round in 1..=cfg.rounds {
        records.push(exp.step(round).unwrap());
    }
    let hash = model_hash(&exp.global);
    transport.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    (records, hash)
}

/// The acceptance bar: real sockets reproduce the loopback run
/// byte-for-byte — records, byte counts, final model hash — under the
/// synchronous policy (all-Ack), the overselecting policy (real Cut
/// frames: remote DGC rollback must mirror the host shadow), and
/// buffered asynchrony (Ack ordering across aggregation windows).
#[test]
fn tcp_run_is_bit_identical_to_loopback_for_every_policy() {
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 4;
        cfg.eval_every = 2;
        cfg.sched.policy = policy.into();
        let (loop_records, loop_hash) = run_loopback(&cfg);
        let (tcp_records, tcp_hash) = run_tcp(&cfg, 2);
        assert_eq!(loop_records.len(), tcp_records.len(), "{policy}");
        for (a, b) in loop_records.iter().zip(&tcp_records) {
            assert_records_equal(a, b, policy);
        }
        assert_eq!(
            loop_hash, tcp_hash,
            "{policy}: final model must hash identically over TCP"
        );
        // Wire accounting is live: frames cost real overhead beyond
        // the codec payload.
        for r in &tcp_records {
            if r.arrived > 0 {
                assert!(r.down_bytes > r.down_payload_bytes, "{policy}");
                assert!(r.up_bytes > r.up_payload_bytes, "{policy}");
            }
        }
    }
}

/// A lone client process can carry the whole fleet (routing is
/// `client % conns`), and raw-uplink (no DGC) runs frame correctly
/// too.
#[test]
fn single_connection_raw_uplink_matches_loopback() {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 3;
    cfg.eval_every = 3;
    cfg.uplink_dgc = false;
    cfg.downlink = "raw".into();
    let (loop_records, loop_hash) = run_loopback(&cfg);
    let (tcp_records, tcp_hash) = run_tcp(&cfg, 1);
    for (a, b) in loop_records.iter().zip(&tcp_records) {
        assert_records_equal(a, b, "raw/1conn");
    }
    assert_eq!(loop_hash, tcp_hash);
}
