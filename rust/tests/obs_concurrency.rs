//! Multi-thread stress for the observability + logging substrate:
//!
//! * the JSONL sink never tears a line under contention (satellite:
//!   every record goes through one locked writer);
//! * counter and histogram totals equal the sum of per-thread
//!   contributions (relaxed atomics lose nothing);
//! * span rings are strictly per-thread: each stress thread's ring
//!   holds exactly the records that thread wrote.

use std::sync::Arc;
use std::thread;

use afd::obs::metrics::{Counter, Histogram};
use afd::util::json::Json;

const THREADS: usize = 8;

#[test]
fn jsonl_sink_never_tears_lines_under_contention() {
    const PER_THREAD: usize = 250;
    let dir = std::env::temp_dir().join("afd_obs_stress");
    let path = dir.join("stress.jsonl");
    let sink = Arc::new(afd::util::logging::JsonlSink::create(&path).unwrap());

    let mut handles = Vec::new();
    for t in 0..THREADS {
        let sink = Arc::clone(&sink);
        handles.push(thread::spawn(move || {
            for i in 0..PER_THREAD {
                let mut rec = Json::obj();
                rec.set("thread", Json::Num(t as f64));
                rec.set("i", Json::Num(i as f64));
                // Long enough that a non-atomic write would interleave.
                rec.set("pad", Json::Str("x".repeat(256)));
                sink.write(&rec);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    drop(sink);

    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), THREADS * PER_THREAD, "lines lost or split");
    let mut per_thread = vec![0usize; THREADS];
    for (n, line) in lines.iter().enumerate() {
        let j = afd::util::json::parse(line)
            .unwrap_or_else(|e| panic!("line {n} torn: {e}\n{line}"));
        let t = j.get("thread").unwrap().as_f64().unwrap() as usize;
        assert_eq!(
            j.get("pad").unwrap().as_str().unwrap().len(),
            256,
            "line {n} truncated"
        );
        per_thread[t] += 1;
    }
    assert!(per_thread.iter().all(|&c| c == PER_THREAD));
    // Nothing failed to write, so nothing was counted as dropped.
    assert_eq!(afd::util::logging::dropped_lines(), 0);
}

#[test]
fn counter_and_histogram_totals_match_per_thread_sums() {
    const PER_THREAD: u64 = 10_000;
    static HITS: Counter = Counter::new();
    static BYTES: Counter = Counter::new();
    static SIZES: Histogram = Histogram::new();

    let mut handles = Vec::new();
    for t in 0..THREADS as u64 {
        handles.push(thread::spawn(move || {
            for i in 0..PER_THREAD {
                HITS.incr();
                BYTES.add(t + 1);
                SIZES.observe(i % 1000);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let n = THREADS as u64;
    assert_eq!(HITS.get(), n * PER_THREAD);
    // Thread t adds (t+1) per iteration: Σ(t+1) = n(n+1)/2 per pass.
    assert_eq!(BYTES.get(), PER_THREAD * n * (n + 1) / 2);
    assert_eq!(SIZES.count(), n * PER_THREAD);
    // Σ (i % 1000) over 10_000 iterations = 10 full cycles of 0..999.
    let cycle: u64 = (0..1000).sum();
    assert_eq!(SIZES.sum(), n * (PER_THREAD / 1000) * cycle);
}

#[test]
#[cfg_attr(not(feature = "trace"), ignore = "needs the trace feature")]
fn span_rings_stay_per_thread_under_contention() {
    const PER_THREAD: usize = 1000;
    afd::obs::set_enabled(true);
    let mut handles = Vec::new();
    for t in 0..THREADS {
        handles.push(
            thread::Builder::new()
                .name(format!("obs-stress-{t}"))
                .spawn(move || {
                    afd::obs::register_thread();
                    for i in 0..PER_THREAD {
                        afd::obs::mark(afd::obs::Stage::Pack, i as u64, t as u64);
                    }
                })
                .unwrap(),
        );
    }
    for h in handles {
        h.join().unwrap();
    }
    afd::obs::set_enabled(false);

    let snap = afd::obs::span::snapshot();
    for t in 0..THREADS {
        let name = format!("obs-stress-{t}");
        let ring = snap
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("no ring registered for {name}"));
        assert_eq!(ring.dropped, 0);
        assert_eq!(ring.spans.len(), PER_THREAD, "{name}");
        // Single-writer rings: this thread's records, in its order.
        for (i, s) in ring.spans.iter().enumerate() {
            assert_eq!(s.stage, afd::obs::Stage::Pack);
            assert_eq!(s.a, i as u64, "{name} record {i}");
            assert_eq!(s.b, t as u64);
        }
    }
}
