//! Property tests on the coordinator's invariants (in-tree `prop`
//! substrate standing in for proptest).

use afd::dropout::{
    kept_count, make_strategy, MultiModelAfd, RandomFd, ScoreMap, SingleModelAfd,
    SubmodelStrategy,
};
use afd::prop::{check, Pair, UsizeIn};
use afd::runtime::native::mlp_spec;
use afd::util::rng::Pcg64;

fn spec_with_hidden(h: usize) -> afd::model::manifest::VariantSpec {
    mlp_spec("p", 6, h, 3, 4, 2, 0.1)
}

#[test]
fn prop_selection_always_keeps_fdr_fraction() {
    // For every strategy, every round, every client: the sub-model keeps
    // exactly kept_count(group, fdr) units per group.
    let gen = Pair(UsizeIn(2, 64), UsizeIn(0, 10_000));
    check("selection size invariant", &gen, 60, |&(h, seed)| {
        let spec = spec_with_hidden(h);
        let fdr = 0.25;
        let mut rng = Pcg64::new(seed as u64);
        for kind in ["fd", "afd_multi", "afd_single"] {
            let mut s = make_strategy(kind, &spec, 5, fdr).unwrap();
            for round in 1..6 {
                for client in 0..3 {
                    let sm = s.select(round, client, &mut rng);
                    let want = kept_count(h, fdr);
                    let got = sm.kept_counts()[0];
                    if got != want {
                        return Err(format!("{kind} r{round} c{client}: {got} != {want}"));
                    }
                    s.report_loss(round, client, 1.0 / round as f64);
                }
                s.end_round(round);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_strategies_are_deterministic_given_rng() {
    let gen = UsizeIn(0, 100_000);
    check("strategy determinism", &gen, 30, |&seed| {
        let spec = spec_with_hidden(16);
        for kind in ["fd", "afd_multi", "afd_single"] {
            let run = |s: u64| {
                let mut strat = make_strategy(kind, &spec, 4, 0.25).unwrap();
                let mut rng = Pcg64::new(s);
                let mut trace = Vec::new();
                for round in 1..5 {
                    for c in 0..2 {
                        let sm = strat.select(round, c, &mut rng);
                        trace.push(sm.kept_indices());
                        strat.report_loss(round, c, 1.0 / (round + c) as f64);
                    }
                    strat.end_round(round);
                }
                trace
            };
            if run(seed as u64) != run(seed as u64) {
                return Err(format!("{kind} not deterministic"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_score_map_total_monotone_under_improvement() {
    // Strictly decreasing losses ⇒ the score map total never decreases
    // and strictly increases after the second round.
    let gen = Pair(UsizeIn(4, 64), UsizeIn(0, 10_000));
    check("score map monotone", &gen, 40, |&(h, seed)| {
        let spec = spec_with_hidden(h);
        let mut s = MultiModelAfd::new(&spec, 1, 0.25);
        let mut rng = Pcg64::new(seed as u64);
        let mut prev_total = 0.0;
        let mut loss = 10.0;
        for round in 1..8 {
            let _ = s.select(round, 0, &mut rng);
            loss *= 0.8;
            s.report_loss(round, 0, loss);
            let total = s.score_map(0).total();
            if total < prev_total - 1e-12 {
                return Err(format!("total fell {prev_total} -> {total}"));
            }
            if round > 2 && total <= 0.0 {
                return Err("no credit accumulated".into());
            }
            prev_total = total;
        }
        Ok(())
    });
}

#[test]
fn prop_recorded_submodel_reused_exactly() {
    // Whenever loss improves, the NEXT selection must be identical
    // (Alg. 1 line 7).
    let gen = UsizeIn(0, 10_000);
    check("recorded reuse", &gen, 40, |&seed| {
        let spec = spec_with_hidden(24);
        let mut s = MultiModelAfd::new(&spec, 1, 0.3);
        let mut rng = Pcg64::new(seed as u64);
        let mut last = None;
        let mut loss = 5.0;
        for round in 1..10 {
            let sm = s.select(round, 0, &mut rng);
            if s.recorded(0) {
                if let Some(prev) = &last {
                    if &sm != prev {
                        return Err(format!("round {round}: recorded but changed"));
                    }
                }
            }
            loss *= 0.9; // improving every round after round 1
            s.report_loss(round, 0, loss);
            last = Some(sm);
        }
        Ok(())
    });
}

#[test]
fn prop_single_model_cohort_consistency() {
    // All clients of a round share one sub-model regardless of cohort
    // size or call order.
    let gen = Pair(UsizeIn(1, 12), UsizeIn(0, 10_000));
    check("single-model cohort", &gen, 40, |&(m, seed)| {
        let spec = spec_with_hidden(20);
        let mut s = SingleModelAfd::new(&spec, 0.25);
        let mut rng = Pcg64::new(seed as u64);
        for round in 1..6 {
            let first = s.select(round, 0, &mut rng);
            for c in 1..m {
                let sm = s.select(round, c, &mut rng);
                if sm != first {
                    return Err(format!("round {round}: client {c} diverged"));
                }
            }
            for c in 0..m {
                s.report_loss(round, c, 1.0 / (round * (c + 1)) as f64);
            }
            s.end_round(round);
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_selection_biases_toward_credit() {
    // Units with overwhelming score must be selected (statistically).
    let gen = UsizeIn(0, 1_000);
    check("weighted bias", &gen, 15, |&seed| {
        let spec = spec_with_hidden(16);
        let mut map = ScoreMap::zeros(&spec);
        let favored =
            afd::model::submodel::SubModel::from_kept_indices(&spec, &[vec![0, 5, 9, 13]]);
        for _ in 0..50 {
            map.credit(&favored, 1.0);
        }
        let mut rng = Pcg64::new(seed as u64);
        let mut favored_hits = 0usize;
        let trials = 50;
        for _ in 0..trials {
            let sm = map.weighted_select(&spec, 0.75, &mut rng); // keep 4 of 16
            favored_hits += sm.kept_indices()[0]
                .iter()
                .filter(|u| [0usize, 5, 9, 13].contains(u))
                .count();
        }
        // Of 4·trials kept slots, the overwhelming majority must be the
        // 4 favored units.
        if favored_hits * 10 >= trials * 4 * 8 {
            Ok(())
        } else {
            Err(format!("favored hits {favored_hits}/{}", trials * 4))
        }
    });
}

#[test]
fn prop_fd_has_no_memory() {
    // FD selections are iid across rounds: reporting different losses
    // must not change the distribution (compare traces under different
    // loss feeds with the same rng seed).
    let gen = UsizeIn(0, 10_000);
    check("fd memoryless", &gen, 30, |&seed| {
        let spec = spec_with_hidden(16);
        let run = |losses: &[f64]| {
            let mut s = RandomFd::new(&spec, 0.25);
            let mut rng = Pcg64::new(seed as u64);
            let mut trace = Vec::new();
            for (round, &l) in losses.iter().enumerate() {
                let sm = s.select(round + 1, 0, &mut rng);
                trace.push(sm.kept_indices());
                s.report_loss(round + 1, 0, l);
                s.end_round(round + 1);
            }
            trace
        };
        let a = run(&[5.0, 4.0, 3.0, 2.0]);
        let b = run(&[1.0, 9.0, 1.0, 9.0]);
        if a == b {
            Ok(())
        } else {
            Err("FD selections depended on losses".into())
        }
    });
}
