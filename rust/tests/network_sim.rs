//! Network-simulation integration: orderings the paper's convergence
//! times rest on must hold across the sampled fleet.

use afd::network::{LinkConfig, NetworkSim};
use afd::prop::{check, Pair, UsizeIn};

#[test]
fn prop_round_time_monotone_in_payload() {
    let gen = Pair(UsizeIn(1, 40), UsizeIn(0, 100_000));
    check("monotone in bytes", &gen, 40, |&(m, seed)| {
        let sim = NetworkSim::new(LinkConfig::default(), m, seed as u64);
        let small: Vec<(usize, u64, f64, u64)> =
            (0..m).map(|c| (c, 100_000, 1e8, 50_000)).collect();
        let large: Vec<(usize, u64, f64, u64)> =
            (0..m).map(|c| (c, 1_000_000, 1e8, 500_000)).collect();
        let ts = sim.round(&small).round_s;
        let tl = sim.round(&large).round_s;
        if tl > ts {
            Ok(())
        } else {
            Err(format!("large {tl} ≤ small {ts}"))
        }
    });
}

#[test]
fn prop_round_time_monotone_in_cohort() {
    // Adding a straggler can only increase the (max-based) round time.
    let gen = UsizeIn(0, 100_000);
    check("monotone in cohort", &gen, 40, |&seed| {
        let sim = NetworkSim::new(LinkConfig::default(), 10, seed as u64);
        let jobs: Vec<(usize, u64, f64, u64)> =
            (0..10).map(|c| (c, 500_000, 5e8, 200_000)).collect();
        let mut prev = 0.0;
        for m in 1..=10 {
            let t = sim.round(&jobs[..m]).round_s;
            if t + 1e-12 < prev {
                return Err(format!("m={m}: {t} < {prev}"));
            }
            prev = t;
        }
        Ok(())
    });
}

#[test]
fn compute_and_transfer_compose() {
    let sim = NetworkSim::new(LinkConfig::default(), 1, 3);
    let t_all = sim.round(&[(0, 1_000_000, 2e9, 400_000)]);
    let t_net = sim.round(&[(0, 1_000_000, 0.0, 400_000)]);
    let link = &sim.links[0];
    let want_compute = 2e9 / link.device_flops;
    let got = t_all.round_s - t_net.round_s;
    assert!(
        (got - want_compute).abs() < 1e-9,
        "compute time should add exactly: {got} vs {want_compute}"
    );
}

#[test]
fn paper_profile_round_times_are_plausible() {
    // A 4-layer CNN-sized payload (~420 KB f32 full model) over 4G LTE
    // should cost on the order of seconds per round — the regime that
    // makes the paper's 3233-minute FEMNIST baseline plausible at scale.
    let sim = NetworkSim::new(LinkConfig::default(), 30, 7);
    let jobs: Vec<(usize, u64, f64, u64)> = (0..9)
        .map(|c| (c, 420_776, 3.0 * 7.8e6 * 50.0, 420_776))
        .collect();
    let t = sim.round(&jobs);
    assert!(
        t.round_s > 0.5 && t.round_s < 10.0,
        "full-model round {}s out of the plausible band",
        t.round_s
    );
    // And a compressed sub-model round is several times cheaper.
    let jobs_c: Vec<(usize, u64, f64, u64)> = (0..9)
        .map(|c| (c, 75_000, 3.0 * 4.5e6 * 50.0, 15_000))
        .collect();
    let tc = sim.round(&jobs_c);
    assert!(
        t.round_s / tc.round_s > 3.0,
        "compression should cut round time ≥3×: {} vs {}",
        t.round_s,
        tc.round_s
    );
}

#[test]
fn fleet_heterogeneity_creates_stragglers() {
    // With sampled links, identical payloads finish at different times —
    // the straggler effect the paper argues synchronous FL suffers from.
    let sim = NetworkSim::new(LinkConfig::default(), 40, 11);
    let jobs: Vec<(usize, u64, f64, u64)> =
        (0..40).map(|c| (c, 1_000_000, 1e9, 1_000_000)).collect();
    let t = sim.round(&jobs);
    let times: Vec<f64> = t.per_client.iter().map(|c| c.total()).collect();
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        max / min > 1.5,
        "expected ≥1.5× straggler spread, got {:.2}",
        max / min
    );
    assert_eq!(t.round_s, max);
}
