//! Property sweep for the deterministic fault-injection engine.
//!
//! The contract under test (see `rust/src/fault/README.md`): for every
//! injection site and every fault seed, a faulted run either
//!
//! * is **bit-identical** to the fault-free run (the fault was masked
//!   by a recovery path), or
//! * completes with a **nonzero typed loss / quarantine count** —
//!
//! and it never panics, never hangs, and never silently diverges.
//!
//! Fault state is process-global, so every test here serializes on one
//! mutex and resets the plan on the way out. Unit tests inside the
//! library never arm the global plan for the same reason.

use std::sync::{Mutex, MutexGuard};

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::fault::{self, Site, ALL_SITES};
use afd::metrics::RoundRecord;
use afd::util::model_hash;

static LOCK: Mutex<()> = Mutex::new(());

/// Take the global-fault-state lock (surviving another test's panic)
/// and guarantee a clean slate on both edges.
fn exclusive() -> MutexGuard<'static, ()> {
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::reset();
    guard
}

fn smoke_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 4;
    cfg.eval_every = 2;
    cfg
}

/// Run over the loopback transport; return the per-round records and
/// the final model hash (the bit-identity handle CI greps).
fn run(cfg: &ExperimentConfig) -> (Vec<RoundRecord>, u64) {
    let mut exp = Experiment::build(cfg).unwrap();
    let mut recs = Vec::new();
    for round in 1..=cfg.rounds {
        recs.push(exp.step(round).unwrap());
    }
    (recs, model_hash(&exp.global))
}

/// Serialized record lines — the same bytes a `--out` JSONL would
/// hold, so "bit-identical" here means what it means in CI.
fn jsonl(recs: &[RoundRecord]) -> Vec<String> {
    recs.iter().map(|r| r.to_json().to_string_compact()).collect()
}

#[test]
fn every_site_and_seed_masks_or_converts_to_typed_loss() {
    let _guard = exclusive();
    let cfg = smoke_cfg();
    let (base_recs, base_hash) = run(&cfg);
    let base_jsonl = jsonl(&base_recs);
    assert!(base_recs.iter().all(|r| r.lost == 0 && r.quarantined == 0));

    for site in ALL_SITES {
        for fseed in [1u64, 2, 3] {
            fault::install(&format!("{}:0.2", site.name()), fseed, 3).unwrap();
            let (recs, hash) = run(&cfg);
            fault::reset();

            let what = format!("site {} seed {fseed}", site.name());
            let identical = jsonl(&recs) == base_jsonl && hash == base_hash;
            let losses: usize = recs.iter().map(|r| r.lost).sum();
            let quarantined = recs.last().unwrap().quarantined;
            if matches!(site, Site::PartialWrite | Site::FrameDup) {
                // Masked by construction: short writes resume from the
                // cursor, duplicate frames are dropped by the matcher.
                assert!(identical, "{what}: a masked site must be bit-identical");
            } else {
                assert!(
                    identical || losses + quarantined > 0,
                    "{what}: diverged from baseline without a typed loss"
                );
            }
        }
    }
}

/// A plan made only of masked sites, at a high rate, must not move a
/// single bit — even though the fault machinery is armed and the
/// engine runs its may-lose paths (rollback snapshots and all).
#[test]
fn masked_only_plan_is_bit_identical_at_high_rate() {
    let _guard = exclusive();
    let cfg = smoke_cfg();
    let (base_recs, base_hash) = run(&cfg);
    fault::install("partial_write:0.9,frame_dup:0.9", 7, 3).unwrap();
    let (recs, hash) = run(&cfg);
    fault::reset();
    assert_eq!(jsonl(&recs), jsonl(&base_recs));
    assert_eq!(hash, base_hash);
}

/// Tracing must stay an observer even while faults fire: a traced
/// faulted run and an untraced faulted run produce identical records.
#[test]
fn tracing_is_bit_identical_under_an_active_plan() {
    let _guard = exclusive();
    let cfg = smoke_cfg();
    fault::install("sock_read:0.3,worker_panic:0.1", 11, 3).unwrap();
    let (plain_recs, plain_hash) = run(&cfg);
    afd::obs::set_enabled(true);
    let (traced_recs, traced_hash) = run(&cfg);
    afd::obs::set_enabled(false);
    fault::reset();
    assert_eq!(jsonl(&traced_recs), jsonl(&plain_recs));
    assert_eq!(traced_hash, plain_hash);
}

/// Clients that fault round after round end up quarantined: the
/// scheduler stops selecting them, the count is policy-visible in the
/// records, and the run still completes cleanly.
#[test]
fn repeat_offenders_are_quarantined() {
    let _guard = exclusive();
    let mut cfg = smoke_cfg();
    cfg.rounds = 6;
    cfg.client_fraction = 0.5; // big cohorts: clients repeat quickly
    let mut saw_quarantine = false;
    for fseed in 1u64..=4 {
        fault::install("sock_write:0.9", fseed, 2).unwrap();
        let (recs, _hash) = run(&cfg);
        fault::reset();
        let losses: usize = recs.iter().map(|r| r.lost).sum();
        assert!(losses > 0, "seed {fseed}: a 90% write-fault rate must lose rounds");
        // Quarantine counts are monotone.
        for w in recs.windows(2) {
            assert!(w[1].quarantined >= w[0].quarantined);
        }
        if recs.last().unwrap().quarantined > 0 {
            saw_quarantine = true;
            break;
        }
    }
    assert!(saw_quarantine, "no fault seed quarantined anyone");
}

/// The fault plan works under every scheduler policy — including the
/// continuous one, whose loss handling runs through `refill` rather
/// than the round loop.
#[test]
fn all_policies_survive_an_aggressive_mixed_plan() {
    let _guard = exclusive();
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut cfg = smoke_cfg();
        cfg.sched.policy = policy.into();
        fault::install(
            "sock_write:0.2,sock_read:0.2,frame_corrupt:0.2,frame_delay:0.2,\
             worker_panic:0.1,clock_stall:0.1",
            3,
            3,
        )
        .unwrap();
        let (recs, _hash) = run(&cfg);
        fault::reset();
        assert_eq!(recs.len(), cfg.rounds, "{policy}: run must complete");
        let losses: usize = recs.iter().map(|r| r.lost).sum();
        assert!(losses > 0, "{policy}: this plan fires on a 4-round smoke");
    }
}
