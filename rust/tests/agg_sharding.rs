//! Determinism-conformance suite for sharded aggregation.
//!
//! The contract under test: [`ShardedFedAvg`] output — `finalize` bits
//! and `coverage` bits — is **bit-identical** to the retained
//! single-threaded [`FedAvg`] reference for every shard count
//! (including 1 and counts larger than the parameter count), for every
//! mix of `add_masked` / `add_full` / `add_planned` calls, and for
//! degenerate inputs (zero clients, zero-weight clients, all-false
//! masks, non-divisible parameter counts, non-finite values).

use std::sync::Arc;

use afd::aggregation::{AddOp, FedAvg, ShardedFedAvg};
use afd::model::packing::{coordinate_mask, PackPlan};
use afd::model::submodel::SubModel;
use afd::prop::{check, Gen};
use afd::runtime::native::mlp_spec;
use afd::util::pool::LazyPool;
use afd::util::rng::Pcg64;

/// One client's contribution to a round.
#[derive(Clone, Debug)]
enum Add {
    Masked {
        values: Vec<f32>,
        mask: Vec<bool>,
        n_c: f64,
    },
    Full {
        values: Vec<f32>,
        n_c: f64,
    },
}

/// A randomized aggregation round: parameter count, previous global
/// (`base`), and a mixed sequence of client adds.
#[derive(Clone, Debug)]
struct Scenario {
    num_params: usize,
    base: Vec<f32>,
    adds: Vec<Add>,
}

struct ScenarioGen;

impl Gen for ScenarioGen {
    type Output = Scenario;

    fn generate(&self, rng: &mut Pcg64) -> Scenario {
        // 1..=257: exercises tiny vectors, primes (indivisible by most
        // shard counts) and sizes below the tested shard counts.
        let n = 1 + rng.below(257) as usize;
        let base = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let clients = rng.below(7) as usize; // 0..=6, zero-client included
        let adds = (0..clients)
            .map(|_| {
                let mut values: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                // Occasionally inject a non-finite value: identical op
                // sequences must yield identical bits even through
                // NaN/∞ propagation.
                if rng.below(8) == 0 {
                    let i = rng.below(n as u64) as usize;
                    values[i] = match rng.below(3) {
                        0 => f32::NAN,
                        1 => f32::INFINITY,
                        _ => f32::NEG_INFINITY,
                    };
                }
                // Mostly real sample counts; sometimes a zero-weight
                // client (contributes nothing to the average).
                let n_c = if rng.below(5) == 0 {
                    0.0
                } else {
                    (1 + rng.below(100)) as f64
                };
                if rng.below(3) == 0 {
                    Add::Full { values, n_c }
                } else {
                    // Mask density drawn per client: p near 0 produces
                    // all-false masks, p near 1 full masks.
                    let p = rng.next_f64();
                    let mask = (0..n).map(|_| rng.next_f64() < p).collect();
                    Add::Masked { values, mask, n_c }
                }
            })
            .collect();
        Scenario {
            num_params: n,
            base,
            adds,
        }
    }

    fn shrink(&self, case: &Scenario) -> Vec<Scenario> {
        // Dropping the last add keeps the scenario well-formed and
        // usually isolates the offending client.
        let mut out = Vec::new();
        if !case.adds.is_empty() {
            let mut c = case.clone();
            c.adds.pop();
            out.push(c);
        }
        out
    }
}

fn apply_reference(s: &Scenario) -> (Vec<u32>, u64) {
    let mut agg = FedAvg::new(s.num_params);
    for add in &s.adds {
        match add {
            Add::Masked { values, mask, n_c } => agg.add_masked(values, mask, *n_c),
            Add::Full { values, n_c } => agg.add_full(values, *n_c),
        }
    }
    let out = agg.finalize(&s.base);
    (bits(&out), agg.coverage().to_bits())
}

fn apply_sharded(agg: &mut ShardedFedAvg, s: &Scenario) -> (Vec<u32>, u64) {
    for add in &s.adds {
        match add {
            Add::Masked { values, mask, n_c } => agg.add_masked(values, mask, *n_c),
            Add::Full { values, n_c } => agg.add_full(values, *n_c),
        }
    }
    let out = agg.finalize(&s.base);
    (bits(&out), agg.coverage().to_bits())
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The acceptance bar: random rounds, five shard counts each (1, 2, 7,
/// pool width, > num_params), replayed twice through `reset` — all
/// bit-identical to the reference.
#[test]
fn sharded_is_bit_identical_to_reference_across_shard_counts() {
    let pool = Arc::new(LazyPool::new(4));
    check("sharded fedavg conformance", &ScenarioGen, 48, |s| {
        let (want, want_cov) = apply_reference(s);
        for shards in [1usize, 2, 7, pool.size(), s.num_params + 5] {
            let mut agg = ShardedFedAvg::new(s.num_params, shards, Arc::clone(&pool));
            let (got, cov) = apply_sharded(&mut agg, s);
            if got != want {
                return Err(format!(
                    "shards={shards}: finalize diverges from FedAvg reference"
                ));
            }
            if cov != want_cov {
                return Err(format!(
                    "shards={shards}: coverage diverges from FedAvg reference"
                ));
            }
            // Round-to-round reuse: reset + replay must reproduce the
            // same bits (the engine resets the accumulator per round).
            agg.reset();
            let (again, cov_again) = apply_sharded(&mut agg, s);
            if again != want || cov_again != want_cov {
                return Err(format!("shards={shards}: reset+replay diverges"));
            }
        }
        Ok(())
    });
}

/// Persistent fan-out conformance (one pool dispatch per round): the
/// batched path — `aggregate_batch` replaying reset, every add and the
/// finalize on pinned shard workers — is bit-identical to the per-add
/// dispatch path (and therefore to the `FedAvg` reference) on random
/// mixed rounds, every shard count, with the output buffer reused
/// across rounds.
#[test]
fn batched_round_is_bit_identical_to_per_add_dispatch() {
    let pool = Arc::new(LazyPool::new(4));
    check("aggregate_batch conformance", &ScenarioGen, 48, |s| {
        let (want, _) = apply_reference(s);
        for shards in [1usize, 2, 7, pool.size(), s.num_params + 5] {
            let mut per_add = ShardedFedAvg::new(s.num_params, shards, Arc::clone(&pool));
            let (via_adds, _) = apply_sharded(&mut per_add, s);
            let mut batched = ShardedFedAvg::new(s.num_params, shards, Arc::clone(&pool));
            let ops: Vec<AddOp> = s
                .adds
                .iter()
                .map(|add| match add {
                    Add::Masked { values, mask, n_c } => AddOp::Masked {
                        values,
                        coord_mask: mask,
                        n_c: *n_c,
                    },
                    Add::Full { values, n_c } => AddOp::Full {
                        values,
                        n_c: *n_c,
                    },
                })
                .collect();
            let mut out = Vec::new();
            batched.aggregate_batch(&ops, &s.base, &mut out);
            if bits(&out) != via_adds || bits(&out) != want {
                return Err(format!(
                    "shards={shards}: batched round diverges from per-add dispatch"
                ));
            }
            // Replay into the same (now warm) output buffer: the batch
            // resets internally, so bits must not change.
            batched.aggregate_batch(&ops, &s.base, &mut out);
            if bits(&out) != want {
                return Err(format!("shards={shards}: batched replay diverges"));
            }
        }
        Ok(())
    });
}

/// Zero clients: finalize returns `base` bitwise, coverage is 0 — for
/// shard counts dividing, not dividing, and exceeding num_params.
#[test]
fn zero_clients_return_base_for_every_shard_count() {
    let pool = Arc::new(LazyPool::new(4));
    let n = 13;
    let base: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
    for shards in [1usize, 5, 13, 29] {
        let mut agg = ShardedFedAvg::new(n, shards, Arc::clone(&pool));
        let out = agg.finalize(&base);
        assert_eq!(bits(&out), bits(&base), "shards={shards}");
        assert_eq!(agg.coverage(), 0.0, "shards={shards}");
    }
}

/// Zero-weight clients and all-false masks leave every coordinate on
/// `base`, exactly as the reference does.
#[test]
fn zero_weight_and_all_false_masks_match_reference() {
    let pool = Arc::new(LazyPool::new(4));
    let n = 37;
    let mut rng = Pcg64::new(5);
    let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let values: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let mut reference = FedAvg::new(n);
    reference.add_full(&values, 0.0); // zero-weight client
    reference.add_masked(&values, &vec![false; n], 9.0); // all-false mask
    let want = reference.finalize(&base);
    assert_eq!(bits(&want), bits(&base), "reference sanity: base survives");

    for shards in [1usize, 4, 36, 50] {
        let mut agg = ShardedFedAvg::new(n, shards, Arc::clone(&pool));
        agg.add_full(&values, 0.0);
        agg.add_masked(&values, &vec![false; n], 9.0);
        let got = agg.finalize(&base);
        assert_eq!(bits(&got), bits(&want), "shards={shards}");
        assert_eq!(
            agg.coverage().to_bits(),
            reference.coverage().to_bits(),
            "shards={shards}"
        );
    }
}

/// `add_planned` (pack-plan contiguous runs) is bit-identical to
/// mask-based adds with the plan's coordinate mask — on the reference
/// and on every shard count, mixed with full and masked adds.
#[test]
fn planned_adds_match_masked_reference() {
    let spec = mlp_spec("agg_conformance", 24, 32, 8, 4, 2, 0.1);
    let n = spec.num_params;
    let pool = Arc::new(LazyPool::new(4));
    let mut rng = Pcg64::new(11);
    let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    for kept_n in [32usize, 20, 5] {
        let kept = rng.sample_indices(32, kept_n);
        let sm = SubModel::from_kept_indices(&spec, &[kept]);
        let plan = PackPlan::build(&spec, &sm);
        let cm = coordinate_mask(&spec, &sm);

        let clients: Vec<(Vec<f32>, f64)> = (0..4)
            .map(|c| {
                let v = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let n_c = if c == 3 { 0.0 } else { 10.0 + c as f64 };
                (v, n_c)
            })
            .collect();
        let extra_full: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let mut reference = FedAvg::new(n);
        for (v, n_c) in &clients {
            reference.add_masked(v, &cm, *n_c);
        }
        reference.add_full(&extra_full, 3.0);
        let want = reference.finalize(&base);

        for shards in [1usize, 3, pool.size(), n + 1] {
            let mut agg = ShardedFedAvg::new(n, shards, Arc::clone(&pool));
            for (v, n_c) in &clients {
                agg.add_planned(v, &plan, *n_c);
            }
            agg.add_full(&extra_full, 3.0);
            let got = agg.finalize(&base);
            assert_eq!(
                bits(&got),
                bits(&want),
                "kept={kept_n} shards={shards}: planned adds must match masked reference"
            );
            assert_eq!(
                agg.coverage().to_bits(),
                reference.coverage().to_bits(),
                "kept={kept_n} shards={shards}"
            );
        }
    }
}

/// Non-finite client values poison exactly their own coordinates:
/// every other coordinate stays finite and bit-identical to the
/// reference, on every shard count (a NaN in shard i must never leak
/// into shard j's slices).
#[test]
fn non_finite_values_only_poison_their_own_coordinates() {
    let pool = Arc::new(LazyPool::new(4));
    let n = 64;
    let poisoned = [5usize, 17, 40];
    let mut rng = Pcg64::new(3);
    let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut bad: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    bad[poisoned[0]] = f32::NAN;
    bad[poisoned[1]] = f32::INFINITY;
    bad[poisoned[2]] = f32::NEG_INFINITY;
    let clean: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let mut reference = FedAvg::new(n);
    reference.add_full(&bad, 7.0);
    reference.add_full(&clean, 3.0);
    let want = reference.finalize(&base);

    for shards in [1usize, 2, 9, 64] {
        let mut agg = ShardedFedAvg::new(n, shards, Arc::clone(&pool));
        agg.add_full(&bad, 7.0);
        agg.add_full(&clean, 3.0);
        let got = agg.finalize(&base);
        assert_eq!(bits(&got), bits(&want), "shards={shards}");
        for (i, v) in got.iter().enumerate() {
            if poisoned.contains(&i) {
                assert!(
                    !v.is_finite(),
                    "shards={shards}: coordinate {i} should carry the poison"
                );
            } else {
                assert!(
                    v.is_finite(),
                    "shards={shards}: coordinate {i} poisoned by another shard"
                );
            }
        }
    }
}

/// `FedAvg::coverage` and `ShardedFedAvg::coverage` agree exactly
/// through partial masks, repeated adds, and the empty aggregator.
#[test]
fn coverage_parity_with_reference() {
    let pool = Arc::new(LazyPool::new(4));
    let n = 101; // prime: never divisible by the shard counts below
    let mut rng = Pcg64::new(17);
    let values: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for shards in [1usize, 2, 7, 25] {
        let mut sharded = ShardedFedAvg::new(n, shards, Arc::clone(&pool));
        let mut reference = FedAvg::new(n);
        assert_eq!(
            sharded.coverage().to_bits(),
            reference.coverage().to_bits(),
            "shards={shards}: empty aggregators"
        );
        for round in 0..3 {
            let p = [0.1, 0.6, 0.95][round];
            let mask: Vec<bool> = (0..n).map(|_| rng.next_f64() < p).collect();
            sharded.add_masked(&values, &mask, 4.0);
            reference.add_masked(&values, &mask, 4.0);
            assert_eq!(
                sharded.coverage().to_bits(),
                reference.coverage().to_bits(),
                "shards={shards} round={round}"
            );
        }
    }
}
