//! Client churn: processes die mid-run and the run survives.
//!
//! 1. **Resume** — a client that crashes after serving a few rounds
//!    and is restarted (fresh process, no token) adopts its dead slot,
//!    receives a `StateSync` plus every still-open `RoundOffer`, and
//!    the run finishes **bit-identical** to loopback: same records,
//!    same byte counts, same final model hash. Churn is invisible to
//!    the learning trajectory.
//! 2. **No resume** — with `transport.resume = false` a dead client's
//!    in-flight rounds convert into policy-visible losses (`lost` in
//!    the round records) and the run still completes instead of
//!    returning `Err`.

use std::sync::Arc;
use std::time::Duration;

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::metrics::RoundRecord;
use afd::runtime::native::mlp_from_config;
use afd::transport::tcp::{run_client_loop, ClientEnd, ClientOptions, TcpServer};
use afd::transport::Transport;
use afd::util::model_hash;

fn assert_records_equal(a: &RoundRecord, b: &RoundRecord, what: &str) {
    assert_eq!(a.round, b.round, "{what}");
    assert_eq!(a.round_s.to_bits(), b.round_s.to_bits(), "{what} round {}", a.round);
    assert_eq!(
        a.train_loss.to_bits(),
        b.train_loss.to_bits(),
        "{what} round {}",
        a.round
    );
    assert_eq!(
        a.eval_acc.map(f64::to_bits),
        b.eval_acc.map(f64::to_bits),
        "{what} round {}",
        a.round
    );
    assert_eq!(a.down_bytes, b.down_bytes, "{what} round {}", a.round);
    assert_eq!(a.up_bytes, b.up_bytes, "{what} round {}", a.round);
    assert_eq!(a.arrived, b.arrived, "{what} round {}", a.round);
    assert_eq!(a.cut, b.cut, "{what} round {}", a.round);
    assert_eq!(a.dropped, b.dropped, "{what} round {}", a.round);
    assert_eq!(a.lost, b.lost, "{what} round {}", a.round);
}

fn run_loopback(cfg: &ExperimentConfig) -> (Vec<RoundRecord>, u64) {
    let mut exp = Experiment::build(cfg).unwrap();
    let mut records = Vec::new();
    for round in 1..=cfg.rounds {
        records.push(exp.step(round).unwrap());
    }
    (records, model_hash(&exp.global))
}

/// A client "process" that crashes after serving `crash_after` rounds,
/// then (if `restart`) is started again as a fresh process — token 0,
/// so it adopts the lowest dead slot and resumes that session.
fn churny_client(
    addr: String,
    crash_after: u64,
    restart: bool,
) -> std::thread::JoinHandle<anyhow::Result<()>> {
    std::thread::spawn(move || {
        let crash = ClientOptions {
            connect_retry_s: 30.0,
            exit_after: Some(crash_after),
            ..ClientOptions::default()
        };
        match run_client_loop(&addr, &crash)? {
            ClientEnd::Bye => return Ok(()),
            ClientEnd::ExitAfter => {}
        }
        if !restart {
            return Ok(());
        }
        let fresh = ClientOptions {
            connect_retry_s: 30.0,
            ..ClientOptions::default()
        };
        let mut last = anyhow::anyhow!("restart never attempted");
        for _ in 0..200 {
            // The replacement can beat the coordinator's EOF detection
            // of the crashed socket, in which case no slot is vacant
            // yet and the handshake is refused — retry briefly, like a
            // process supervisor would.
            match run_client_loop(&addr, &fresh) {
                Ok(ClientEnd::Bye) => return Ok(()),
                Ok(ClientEnd::ExitAfter) => unreachable!("no exit_after on restart"),
                Err(e) => last = e,
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        Err(anyhow::anyhow!("restarted client never re-joined: {last}"))
    })
}

fn run_tcp_with_churn(
    cfg: &ExperimentConfig,
    conns: usize,
    crash_after: u64,
    restart: bool,
) -> (Vec<RoundRecord>, u64) {
    let (_, spec) = mlp_from_config(cfg);
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let mut handles = vec![churny_client(addr.clone(), crash_after, restart)];
    for _ in 1..conns {
        let a = addr.clone();
        let opts = ClientOptions {
            connect_retry_s: 30.0,
            ..ClientOptions::default()
        };
        handles.push(std::thread::spawn(move || {
            run_client_loop(&a, &opts).map(|_| ())
        }));
    }
    let transport = server
        .accept_clients(
            conns,
            &cfg.to_json().to_string_compact(),
            spec.layout_fingerprint(),
            &cfg.transport,
        )
        .unwrap();
    let transport: Arc<dyn Transport> = Arc::new(transport);
    let mut exp = Experiment::build_with_transport(cfg, Arc::clone(&transport)).unwrap();
    let mut records = Vec::new();
    for round in 1..=cfg.rounds {
        records.push(exp.step(round).unwrap());
    }
    let hash = model_hash(&exp.global);
    transport.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    (records, hash)
}

/// The PR-8 acceptance bar: kill a client mid-run, restart it, and the
/// session-resume path (slot adoption + StateSync + offer replay)
/// keeps the whole run bit-identical to loopback.
#[test]
fn killed_and_restarted_client_resumes_bit_identically() {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 5;
    cfg.eval_every = 5;
    let (loop_records, loop_hash) = run_loopback(&cfg);
    let (tcp_records, tcp_hash) = run_tcp_with_churn(&cfg, 2, 2, true);
    assert_eq!(loop_records.len(), tcp_records.len());
    for (a, b) in loop_records.iter().zip(&tcp_records) {
        assert_records_equal(a, b, "churn+resume");
    }
    // Nothing was lost: the crash window was bridged by replay.
    assert!(tcp_records.iter().all(|r| r.lost == 0));
    assert_eq!(
        loop_hash, tcp_hash,
        "resumed run must converge to the identical model"
    );
}

/// With resume disabled a permanent client death degrades gracefully:
/// every round still returns a record, and the dead connection's
/// in-flight clients show up as `lost` instead of erroring the run.
#[test]
fn dead_client_without_resume_converts_to_losses() {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 5;
    cfg.eval_every = 5;
    cfg.transport.resume = false;
    let (records, _hash) = run_tcp_with_churn(&cfg, 2, 1, false);
    assert_eq!(records.len(), cfg.rounds);
    let lost: usize = records.iter().map(|r| r.lost).sum();
    assert!(lost > 0, "the dead connection's rounds must surface as losses");
    // The surviving connection keeps delivering updates.
    assert!(records.iter().map(|r| r.arrived).sum::<usize>() > 0);
}
