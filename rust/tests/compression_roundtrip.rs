//! Compression integration: Rust codecs against each other and against
//! the Pallas hadamard kernel artifact (when built).

use afd::compression::quant::HadamardQuant8;
use afd::compression::{dgc, make_dense_codec, DenseCodec, RawF32};
use afd::model::manifest::Manifest;
use afd::prop::{check, F32Vec};
use afd::util::rng::Pcg64;

#[test]
fn quant8_roundtrip_property() {
    let codec = HadamardQuant8::default();
    let gen = F32Vec {
        min_len: 1,
        max_len: 5000,
        sigma: 2.0,
    };
    check("quant8 roundtrip error bound", &gen, 60, |xs| {
        let enc = codec.encode(xs, 42);
        let dec = codec.decode(&enc, 42);
        if dec.len() != xs.len() {
            return Err(format!("length {} != {}", dec.len(), xs.len()));
        }
        // Error bound: per-block linf ≤ scale·√B/127 where scale ≤
        // max|rotated| ≤ √B·max|x| — use a generous global bound.
        let max_abs = xs.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let bound = max_abs * 0.6 + 1e-6;
        for (i, (a, b)) in xs.iter().zip(&dec).enumerate() {
            if (a - b).abs() > bound {
                return Err(format!("coord {i}: {a} vs {b} (bound {bound})"));
            }
        }
        Ok(())
    });
}

#[test]
fn quant8_compression_ratio_property() {
    let codec = HadamardQuant8::default();
    let gen = F32Vec {
        min_len: 1024,
        max_len: 50_000,
        sigma: 1.0,
    };
    check("quant8 ~4x smaller than raw", &gen, 20, |xs| {
        let raw = RawF32.encode(xs, 0).wire_bytes();
        let q = codec.encode(xs, 0).wire_bytes();
        if q * 3 < raw {
            Ok(())
        } else {
            Err(format!("raw {raw} vs quant {q}"))
        }
    });
}

#[test]
fn dgc_mass_conservation_property() {
    // Without momentum/clipping, decoded mass + residual == input mass.
    let gen = F32Vec {
        min_len: 64,
        max_len: 4096,
        sigma: 1.0,
    };
    check("dgc conserves mass", &gen, 30, |xs| {
        let mut st = dgc::DgcState::new(dgc::DgcConfig {
            sparsity: 0.05,
            momentum: 0.0,
            clip_norm: None,
        });
        let mut shipped = vec![0.0f32; xs.len()];
        for _ in 0..10 {
            let out = dgc::decode(&st.compress(xs));
            afd::tensor::add_assign(&mut shipped, &out);
        }
        // After r rounds of the SAME delta: shipped + residual = 10·xs.
        let resid = st.residual_l2();
        let mut want = xs.clone();
        afd::tensor::scale(10.0, &mut want);
        let mut diff = vec![0.0f32; xs.len()];
        afd::tensor::sub(&want, &shipped, &mut diff);
        let gap = (afd::tensor::l2_norm(&diff) - resid).abs();
        if gap < 1e-2 * (want.len() as f32).max(1.0) {
            Ok(())
        } else {
            Err(format!("mass gap {gap} (residual {resid})"))
        }
    });
}

#[test]
fn rust_quant_matches_pallas_artifact() {
    // The Rust codec and the Pallas kernel implement the same transform;
    // their reconstructions must be close (identical block size + scale
    // logic; signs differ by seed derivation, so compare distortion, not
    // bits).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let k = manifest.kernels.clone().expect("kernel artifacts");
    let client = xla::PjRtClient::cpu().unwrap();
    let exe =
        afd::runtime::pjrt::compile_kernel_artifact(&client, &manifest, &k.hadamard_hlo)
            .unwrap();

    let mut rng = Pcg64::new(5);
    let len = k.hadamard_len;
    let xs: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let signs = Pcg64::new(1234).rademacher(len);

    // Pallas path.
    let lits = [
        afd::runtime::literal::f32_literal(&xs, &[len]).unwrap(),
        afd::runtime::literal::f32_literal(&signs, &[len]).unwrap(),
    ];
    let res = exe.execute::<xla::Literal>(&lits).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let pallas_out = afd::runtime::literal::to_f32_vec(&res).unwrap();

    // Rust path (block size must match the artifact's).
    let codec = HadamardQuant8::new(k.hadamard_block);
    let rust_out = codec.decode(&codec.encode(&xs, 77), 77);

    let pallas_err = afd::tensor::rel_l2_error(&pallas_out, &xs) as f64;
    let rust_err = afd::tensor::rel_l2_error(&rust_out, &xs) as f64;
    // Same algorithm ⇒ same distortion magnitude (within 20%).
    assert!(pallas_err > 0.0 && rust_err > 0.0);
    let ratio = pallas_err / rust_err;
    assert!(
        (0.8..1.25).contains(&ratio),
        "distortion mismatch: pallas {pallas_err:.5} vs rust {rust_err:.5}"
    );
}

#[test]
fn codec_factory_roundtrips_on_model_sized_payloads() {
    let mut rng = Pcg64::new(8);
    let xs: Vec<f32> = (0..105_194).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    for kind in ["raw", "quant8"] {
        let codec = make_dense_codec(kind).unwrap();
        let enc = codec.encode(&xs, 3);
        let dec = codec.decode(&enc, 3);
        assert_eq!(dec.len(), xs.len());
        let err = afd::tensor::rel_l2_error(&dec, &xs);
        match kind {
            "raw" => assert_eq!(err, 0.0),
            _ => assert!(err < 0.02, "{kind}: rel err {err}"),
        }
    }
}
