//! Observability conformance: tracing must be *invisible* in results.
//!
//! The contract (see `rust/src/obs/`): instrumentation only reads and
//! times — it never draws randomness, reorders work, or touches a byte
//! stream. So a fixed-seed run with span/metric recording fully live
//! must produce bit-identical per-round records (every field, compared
//! through the exact JSONL serialization the CLI writes) and a
//! bit-identical final global model, for every scheduler policy.
//!
//! One test function drives all three policies back-to-back: the
//! enable flag and the metrics registry are process-global, so the
//! traced/untraced pairs must not interleave with each other.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::obs::Stage;

/// Run one experiment, returning each round's record exactly as the
/// CLI would serialize it to JSONL, plus the final model hash.
fn run_records(cfg: &ExperimentConfig) -> (Vec<String>, u64) {
    let mut exp = Experiment::build(cfg).unwrap();
    let mut lines = Vec::with_capacity(cfg.rounds);
    for round in 1..=cfg.rounds {
        let rec = exp.step(round).unwrap();
        lines.push(rec.to_json().to_string_compact());
    }
    (lines, afd::util::model_hash(&exp.global))
}

#[test]
fn traced_run_is_bit_identical_to_untraced_for_every_policy() {
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 5;
        cfg.eval_every = 2;
        cfg.uplink_dgc = true;
        cfg.sched.policy = policy.into();

        afd::obs::reset();
        afd::obs::set_enabled(false);
        let (plain, plain_hash) = run_records(&cfg);

        afd::obs::reset();
        afd::obs::set_enabled(true);
        let (traced, traced_hash) = run_records(&cfg);
        let was_live = afd::obs::enabled();
        afd::obs::set_enabled(false);

        assert_eq!(plain.len(), traced.len(), "{policy}: round count diverged");
        for (a, b) in plain.iter().zip(&traced) {
            assert_eq!(a, b, "{policy}: a round record diverged under tracing");
        }
        assert_eq!(
            plain_hash, traced_hash,
            "{policy}: final model hash diverged under tracing"
        );

        // With the trace feature compiled in, the traced run really
        // recorded every pipeline stage (otherwise the identity claim
        // above would be vacuous) — and the trace/stats exporters
        // produce parseable documents from real data.
        if was_live {
            for stage in [
                Stage::EpochAssembly,
                Stage::Pack,
                Stage::Unpack,
                Stage::CodecEncode,
                Stage::CodecDecode,
                Stage::Train,
                Stage::DgcCompress,
                Stage::ShardAggregate,
                Stage::FrameEncode,
                Stage::FrameParse,
                Stage::RoundTrip,
            ] {
                assert!(
                    afd::obs::metrics::STAGE_NS[stage as usize].count() > 0,
                    "{policy}: traced run recorded no {} span",
                    stage.name()
                );
            }
            assert!(
                afd::obs::metrics::ROUNDS_COMPLETED.get() >= cfg.rounds as u64,
                "{policy}: rounds_completed counter did not advance"
            );
            assert!(afd::obs::metrics::BYTES_DOWN_WIRE.get() > 0, "{policy}");
            assert!(afd::obs::metrics::BYTES_UP_WIRE.get() > 0, "{policy}");

            let trace = afd::obs::export::chrome_trace_json().to_string_compact();
            let doc = afd::util::json::parse(&trace).unwrap();
            let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
            let has = |name: &str| {
                events
                    .iter()
                    .any(|e| e.get("name").and_then(|n| n.as_str()) == Some(name))
            };
            for name in ["train", "codec_encode", "frame_parse", "shard_aggregate", "round"] {
                assert!(has(name), "{policy}: trace export lost {name} events");
            }
            let stats = afd::obs::export::stats_json().to_string_pretty();
            afd::util::json::parse(&stats).unwrap();
        }
    }
}
