//! Integration: load + compile + execute the AOT artifacts through PJRT.
//!
//! Requires `make artifacts`. Tests are skipped (with a note) if the
//! artifacts directory is absent so `cargo test` stays green on a fresh
//! checkout.

use afd::model::manifest::{DType, Manifest};
use afd::model::submodel::SubModel;
use afd::runtime::pjrt::{compile_kernel_artifact, PjrtRuntime};
use afd::runtime::{BatchInput, EpochData, EvalBatch, ModelRuntime};
use afd::util::rng::Pcg64;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn synth_epoch(spec: &afd::model::manifest::VariantSpec, seed: u64) -> EpochData {
    let mut rng = Pcg64::new(seed);
    let per: usize = spec.input_shape.iter().product();
    let n = spec.num_batches * spec.batch_size;
    let ys: Vec<i32> = (0..n).map(|_| rng.below(spec.classes as u64) as i32).collect();
    let xs = match spec.input_dtype {
        DType::F32 => BatchInput::F32(
            (0..n * per).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        ),
        DType::I32 => BatchInput::I32(
            (0..n * per)
                .map(|_| rng.below(spec.vocab.max(2) as u64) as i32)
                .collect(),
        ),
    };
    EpochData { xs, ys }
}

#[test]
fn all_variants_train_and_eval() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    for name in manifest.variants.keys() {
        let rt = PjrtRuntime::load(&client, &manifest, name).unwrap();
        let spec = rt.spec().clone();
        let params = manifest.load_init_params(&spec).unwrap();
        let sm = SubModel::full(&spec);
        let data = synth_epoch(&spec, 7);

        let out = rt
            .train_epoch(&params, &sm.masks_f32(), &data, spec.lr)
            .unwrap();
        assert_eq!(out.params.len(), spec.num_params);
        assert!(out.mean_loss.is_finite(), "{name}: loss must be finite");
        assert!(out.mean_loss > 0.0, "{name}: xent loss must be positive");
        assert!(
            out.params.iter().zip(&params).any(|(a, b)| a != b),
            "{name}: training must change parameters"
        );

        // Repeated epochs on the same (memorizable) data must reduce loss.
        let out2 = rt
            .train_epoch(&out.params, &sm.masks_f32(), &data, spec.lr)
            .unwrap();
        let out3 = rt
            .train_epoch(&out2.params, &sm.masks_f32(), &data, spec.lr)
            .unwrap();
        assert!(
            out3.mean_loss < out.mean_loss,
            "{name}: loss should fall: {} -> {} -> {}",
            out.mean_loss,
            out2.mean_loss,
            out3.mean_loss
        );

        // Eval runs and counts sanely.
        let per: usize = spec.input_shape.iter().product();
        let batch = EvalBatch {
            xs: match &data.xs {
                BatchInput::F32(v) => BatchInput::F32(v[..spec.batch_size * per].to_vec()),
                BatchInput::I32(v) => BatchInput::I32(v[..spec.batch_size * per].to_vec()),
            },
            ys: data.ys[..spec.batch_size].to_vec(),
        };
        let ev = rt.evaluate(&out3.params, &batch).unwrap();
        assert_eq!(ev.count, spec.batch_size);
        assert!(ev.loss_sum.is_finite() && ev.loss_sum > 0.0);
        assert!(ev.correct >= 0.0 && ev.correct <= spec.batch_size as f64);
        eprintln!(
            "{name}: loss {:.4} -> {:.4}, eval acc {:.2}",
            out.mean_loss,
            out3.mean_loss,
            ev.accuracy()
        );
    }
}

#[test]
fn masked_training_freezes_dropped_units_via_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = PjrtRuntime::load(&client, &manifest, "femnist_small").unwrap();
    let spec = rt.spec().clone();
    let params = manifest.load_init_params(&spec).unwrap();

    // Drop 25% of each group (paper's FDR default).
    let mut rng = Pcg64::new(11);
    let kept: Vec<Vec<usize>> = spec
        .mask_groups
        .iter()
        .map(|g| {
            let keep = (g.size * 3) / 4;
            rng.sample_indices(g.size, keep)
        })
        .collect();
    let sm = SubModel::from_kept_indices(&spec, &kept);
    let data = synth_epoch(&spec, 13);
    let out = rt
        .train_epoch(&params, &sm.masks_f32(), &data, spec.lr)
        .unwrap();

    // Every coordinate outside the sub-model must be bit-identical.
    let cm = afd::model::packing::coordinate_mask(&spec, &sm);
    let mut frozen_checked = 0usize;
    for i in 0..spec.num_params {
        if !cm[i] {
            assert_eq!(out.params[i], params[i], "coordinate {i} must not move");
            frozen_checked += 1;
        }
    }
    assert!(frozen_checked > 0, "sub-model must actually drop something");
    // And the sub-model must have learned.
    assert!(out.params.iter().zip(&params).any(|(a, b)| a != b));
}

#[test]
fn kernel_artifacts_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Some(k) = manifest.kernels.clone() else {
        panic!("manifest missing kernel artifacts")
    };
    let client = xla::PjRtClient::cpu().unwrap();

    // masked_dense: y = relu(x @ w + b) * mask — cross-check vs native.
    let exe = compile_kernel_artifact(&client, &manifest, &k.masked_dense_hlo).unwrap();
    let (m, kk, n) = k.masked_dense_dims;
    let mut rng = Pcg64::new(3);
    let x: Vec<f32> = (0..m * kk).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let w: Vec<f32> = (0..kk * n).map(|_| rng.normal_f32(0.0, 0.3)).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
    let lits = [
        afd::runtime::literal::f32_literal(&x, &[m, kk]).unwrap(),
        afd::runtime::literal::f32_literal(&w, &[kk, n]).unwrap(),
        afd::runtime::literal::f32_literal(&b, &[n]).unwrap(),
        afd::runtime::literal::f32_literal(&mask, &[n]).unwrap(),
    ];
    let res = exe.execute::<xla::Literal>(&lits).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let got = afd::runtime::literal::to_f32_vec(&res).unwrap();
    assert_eq!(got.len(), m * n);
    // Native reference.
    let mut want = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = b[j];
            for t in 0..kk {
                acc += x[i * kk + t] * w[t * n + j];
            }
            want[i * n + j] = acc.max(0.0) * mask[j];
        }
    }
    let err = afd::tensor::rel_l2_error(&got, &want);
    assert!(err < 1e-5, "masked_dense rel err {err}");

    // hadamard roundtrip: ‖out - in‖∞ bounded by quantization step.
    let exe = compile_kernel_artifact(&client, &manifest, &k.hadamard_hlo).unwrap();
    let len = k.hadamard_len;
    let v: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let signs = Pcg64::new(99).rademacher(len);
    let lits = [
        afd::runtime::literal::f32_literal(&v, &[len]).unwrap(),
        afd::runtime::literal::f32_literal(&signs, &[len]).unwrap(),
    ];
    let res = exe.execute::<xla::Literal>(&lits).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let got = afd::runtime::literal::to_f32_vec(&res).unwrap();
    let max_err = v
        .iter()
        .zip(&got)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 0.25, "hadamard roundtrip max err {max_err}");
    assert!(max_err > 0.0, "quantization must not be lossless");
}
