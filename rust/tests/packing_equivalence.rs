//! Packing on the real model manifests: byte accounting and round-trip
//! correctness for every lowered variant, plus property tests.

use afd::model::manifest::Manifest;
use afd::model::packing;
use afd::model::submodel::SubModel;
use afd::prop::{check, UsizeIn};
use afd::util::rng::Pcg64;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).unwrap())
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn random_submodel(
    spec: &afd::model::manifest::VariantSpec,
    fdr: f64,
    rng: &mut Pcg64,
) -> SubModel {
    let kept: Vec<Vec<usize>> = spec
        .mask_groups
        .iter()
        .map(|g| {
            let keep = afd::dropout::kept_count(g.size, fdr);
            rng.sample_indices(g.size, keep)
        })
        .collect();
    SubModel::from_kept_indices(spec, &kept)
}

#[test]
fn pack_unpack_roundtrip_all_variants() {
    let Some(man) = manifest() else { return };
    let mut rng = Pcg64::new(1);
    for spec in man.variants.values() {
        let params = man.load_init_params(spec).unwrap();
        for fdr in [0.0, 0.25, 0.5] {
            let sm = random_submodel(spec, fdr, &mut rng);
            let packed = packing::pack_values(spec, &params, &sm);
            assert_eq!(packed.len(), packing::packed_model_elems(spec, &sm));

            let mut out = vec![f32::NAN; spec.num_params];
            packing::unpack_values(spec, &packed, &sm, &mut out);
            let cm = packing::coordinate_mask(spec, &sm);
            for i in 0..spec.num_params {
                if cm[i] {
                    assert_eq!(out[i], params[i], "{}: coord {i}", spec.name);
                } else {
                    assert!(out[i].is_nan(), "{}: coord {i} touched", spec.name);
                }
            }
        }
    }
}

#[test]
fn fdr25_saves_expected_fraction() {
    // At FDR 25% the transmissible payload must shrink. How much is
    // architecture-dependent: the CNN's dense layer has both rows and
    // cols masked (≈ 0.75² on the biggest tensor), while LSTMs mask only
    // non-recurrent connections (inter-layer + head rows), so their
    // structural saving is small — quantization carries the downlink
    // saving for them (exactly the paper's situation: "dropping
    // activations would not save any space" in some layers).
    let Some(man) = manifest() else { return };
    let mut rng = Pcg64::new(2);
    for spec in man.variants.values() {
        let full = SubModel::full(spec);
        let full_elems = packing::packed_model_elems(spec, &full);
        let sm = random_submodel(spec, 0.25, &mut rng);
        let sub_elems = packing::packed_model_elems(spec, &sm);
        let ratio = sub_elems as f64 / full_elems as f64;
        let max_ratio = if spec.kind == "cnn" { 0.85 } else { 0.985 };
        assert!(
            ratio < max_ratio,
            "{}: FDR 25% should save params, ratio {ratio:.3}",
            spec.name
        );
        assert!(ratio > 0.4, "{}: ratio suspiciously low {ratio:.3}", spec.name);
        // FLOPs shrink too (the paper's computation saving). LSTM
        // recurrent units keep computing even when their upward output
        // is dropped, so their compute saving is correspondingly small.
        let f_full = packing::effective_flops_per_sample(spec, &full);
        let f_sub = packing::effective_flops_per_sample(spec, &sm);
        let max_f = if spec.kind == "cnn" { 0.9 } else { 0.99 };
        assert!(
            f_sub < f_full * max_f,
            "{}: flops {f_sub} vs {f_full}",
            spec.name
        );
    }
}

#[test]
fn frozen_embeddings_never_packed() {
    let Some(man) = manifest() else { return };
    let spec = man.variant("sent140_small").unwrap();
    let embed = spec.param("embed").unwrap();
    assert!(!embed.transmit);
    let full = SubModel::full(spec);
    let elems = packing::packed_model_elems(spec, &full);
    assert_eq!(
        elems,
        spec.num_params - embed.size,
        "embedding must not count toward wire size"
    );
    let cm = packing::coordinate_mask(spec, &full);
    for i in embed.range() {
        assert!(!cm[i]);
    }
}

#[test]
fn packed_size_monotone_in_kept_units() {
    // Property: adding a kept unit never shrinks the packed model.
    let Some(man) = manifest() else { return };
    let spec = man.variant("femnist_small").unwrap().clone();
    let gen = UsizeIn(0, 1_000_000);
    check("packing monotone", &gen, 25, |&seed| {
        let mut rng = Pcg64::new(seed as u64);
        let sm_small = random_submodel(&spec, 0.5, &mut rng);
        // Grow: add one dropped unit back in group 0.
        let mut keep = sm_small.keep.clone();
        if let Some(pos) = keep[0].iter().position(|&k| !k) {
            keep[0][pos] = true;
        }
        let sm_big = SubModel::from_keep(keep);
        let small = packing::packed_model_elems(&spec, &sm_small);
        let big = packing::packed_model_elems(&spec, &sm_big);
        if big >= small {
            Ok(())
        } else {
            Err(format!("grew {small} -> {big}"))
        }
    });
}

#[test]
fn lstm_recurrent_rows_always_transmitted() {
    // The fixed (recurrent) block of lstm2_w must survive any sub-model:
    // masking is non-recurrent only.
    let Some(man) = manifest() else { return };
    let spec = man.variant("shakespeare_small").unwrap();
    let l2 = spec.param("lstm2_w").unwrap();
    let hidden = spec.mask_groups[0].size;
    let mut rng = Pcg64::new(3);
    let sm = random_submodel(spec, 0.5, &mut rng);
    let cm = packing::coordinate_mask(spec, &sm);
    // Rows [hidden .. 2*hidden) of lstm2_w are the recurrent block.
    let stride = l2.cols_extent();
    for r in hidden..2 * hidden {
        for c in 0..stride {
            assert!(
                cm[l2.offset + r * stride + c],
                "recurrent row {r} col {c} must be in every sub-model"
            );
        }
    }
}

#[test]
fn wire_bytes_match_network_savings_claim() {
    // Sanity: quant8(packed submodel) at FDR 25% vs the raw full model —
    // the combined downlink saving the paper banks on. CNN: dropping ×
    // quantization ≳ 5×; LSTM: quantization-dominated ≳ 3.9×.
    let Some(man) = manifest() else { return };
    use afd::compression::{quant::HadamardQuant8, DenseCodec};
    let codec = HadamardQuant8::default();
    let mut rng = Pcg64::new(4);
    for spec in man.variants.values() {
        let params = man.load_init_params(spec).unwrap();
        let full_raw = spec.transmit_bytes_full() as f64;
        let sm = random_submodel(spec, 0.25, &mut rng);
        let packed = packing::pack_values(spec, &params, &sm);
        let wire = codec.encode(&packed, 9).wire_bytes() as f64;
        let min_factor = if spec.kind == "cnn" { 5.0 } else { 3.9 };
        assert!(
            wire * min_factor < full_raw,
            "{}: wire {wire} vs full raw {full_raw} (want ≥{min_factor}×)",
            spec.name
        );
    }
}
