//! Population-engine conformance: lazy `(seed, id)` derivation must
//! reproduce the eager fleet bitwise, the residual store must round-trip
//! evicted state exactly, and a 100k-client / 10k-cohort round must
//! complete with resident state bounded by the configured byte budget.

use std::sync::Arc;

use afd::clients::{client_rng, Population, PopulationConfig};
use afd::compression::dgc::DgcConfig;
use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::data::{lazy, DataConfig};
use afd::network::{LinkConfig, NetworkSim};
use afd::runtime::native::mlp_spec;
use afd::runtime::BatchInput;
use afd::util::rng::Pcg64;

fn data_cfg(seed: u64, n: usize, iid: bool) -> DataConfig {
    DataConfig {
        num_clients: n,
        samples_per_client: (12, 20),
        iid,
        test_fraction: 0.2,
        seed,
    }
}

/// Property: for random `(seed, id)` pairs probed in random order, a
/// lazily-derived client is indistinguishable — bitwise — from the
/// corresponding entry of an eager fleet built over the same derivation:
/// same sample count, same RNG stream, same epoch draws, same link
/// parameters.
#[test]
fn lazy_client_matches_eager_fleet_entry_bitwise() {
    for (seed, n, iid) in [(0u64, 64usize, false), (9, 33, true), (1234, 17, false)] {
        let spec = mlp_spec("p", 12, 8, 4, 6, 2, 0.1);
        let dc = data_cfg(seed, n, iid);
        let dataset = Arc::new(lazy::generate_lazy(&spec, &dc));
        let mut eager = Population::eager(
            Arc::clone(&dataset),
            DgcConfig::default(),
            seed,
            &PopulationConfig::default(),
        );
        let mut lazy_pop = Population::lazy(
            spec.clone(),
            dc.clone(),
            DgcConfig::default(),
            seed,
            &PopulationConfig::default(),
        );
        assert!(lazy_pop.is_lazy() && !eager.is_lazy());

        let mut probe = Pcg64::new(seed ^ 0x9e37);
        for _ in 0..24 {
            let c = probe.below(n as u64) as usize;
            assert_eq!(eager.num_samples(c), lazy_pop.num_samples(c), "id {c}");
            // Epoch draws advance both private RNG streams in lockstep
            // and must produce bit-identical batches.
            let a = eager.epoch_data(c, &spec);
            let b = lazy_pop.epoch_data(c, &spec);
            assert_eq!(a.ys, b.ys, "seed {seed} id {c}");
            match (&a.xs, &b.xs) {
                (BatchInput::F32(x), BatchInput::F32(y)) => {
                    assert_eq!(x.len(), y.len());
                    for (p, q) in x.iter().zip(y) {
                        assert_eq!(p.to_bits(), q.to_bits(), "seed {seed} id {c}");
                    }
                }
                _ => panic!("synthetic epochs must be dense f32"),
            }
            // The advanced RNG positions still agree, and both equal
            // the pure derivation's stream.
            let x = eager.client(c).rng.next_u64();
            let y = lazy_pop.client(c).rng.next_u64();
            assert_eq!(x, y, "seed {seed} id {c}");
        }
        // A never-sampled client's stream equals the pure derivation.
        let fresh = n - 1;
        let mut derived = client_rng(seed, fresh);
        assert_eq!(lazy_pop.client(fresh).rng.next_u64(), derived.next_u64());

        // Link parameters: the lazy table-free sim derives the same
        // links the eager table caches.
        let net_e = NetworkSim::new(LinkConfig::default(), n, seed);
        let net_l = NetworkSim::lazy(LinkConfig::default(), seed);
        for c in 0..n {
            let (a, b) = (net_e.link(c), net_l.link(c));
            assert_eq!(a.down_bps.to_bits(), b.down_bps.to_bits(), "id {c}");
            assert_eq!(a.up_bps.to_bits(), b.up_bps.to_bits(), "id {c}");
            assert_eq!(a.device_flops.to_bits(), b.device_flops.to_bits(), "id {c}");
        }
    }
}

/// Property: eviction + rehydration round-trips a client's mutable
/// state exactly — live DGC residuals (from real compress calls), the
/// advanced RNG position, and the participation count all come back
/// bit-identical after the budget pages the client out to the spill
/// file.
#[test]
fn eviction_rehydration_roundtrips_state_exactly() {
    let spec = mlp_spec("e", 12, 8, 4, 6, 2, 0.1);
    let n_params = spec.num_params;
    for seed in [0u64, 7, 42] {
        let dc = data_cfg(seed, 8, false);
        // A 1-byte budget evicts every resident at each end_round.
        let mut pop = Population::lazy(
            spec.clone(),
            dc,
            DgcConfig::default(),
            seed,
            &PopulationConfig {
                lazy: true,
                store_budget_bytes: 1,
                spill_dir: String::new(),
            },
        );

        let mut rng = Pcg64::new(seed ^ 0xd6c);
        let mut snapshots = Vec::new();
        for c in 0..8usize {
            let delta: Vec<f32> = (0..n_params).map(|_| rng.normal_f32(0.0, 0.3)).collect();
            let st = pop.client(c);
            st.participations += 3 + c;
            let _ = st.rng.next_u64(); // advance the stream mid-run
            let (mut scratch, mut msg) = (Vec::new(), Vec::new());
            st.dgc.compress_into(&delta, &mut scratch, &mut msg);
            let (u, v) = st.dgc.residuals();
            snapshots.push((
                st.participations,
                u.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            ));
        }
        pop.end_round();
        assert_eq!(pop.store().resident_len(), 0, "budget must evict everyone");
        assert_eq!(pop.store().spilled_len(), 8);

        // Rehydrate in a different order than eviction.
        for c in (0..8usize).rev() {
            let st = pop.client(c);
            let (participations, u_bits, v_bits) = &snapshots[c];
            assert_eq!(st.participations, *participations, "seed {seed} id {c}");
            let (u, v) = st.dgc.residuals();
            assert_eq!(u.len(), u_bits.len());
            for (x, want) in u.iter().zip(u_bits) {
                assert_eq!(x.to_bits(), *want, "seed {seed} id {c} u");
            }
            for (x, want) in v.iter().zip(v_bits) {
                assert_eq!(x.to_bits(), *want, "seed {seed} id {c} v");
            }
        }
    }
}

/// The scale acceptance bar: a fixed-seed run over a 100 000-client
/// lazy population with a 10 000-client cohort completes, learns
/// something, and ends every round with resident store state under the
/// byte budget while the overflow lives in the spill file.
#[test]
fn hundred_k_clients_ten_k_cohort_stays_within_budget() {
    let mut cfg = ExperimentConfig::preset(Preset::NativePopulation);
    cfg.rounds = 2;
    cfg.eval_every = 3; // final round still evaluates
    cfg.client_fraction = 0.1; // 10k-client cohort
    cfg.native_dims = (12, 8, 4); // keep per-client work tiny
    cfg.data.samples_per_client = (8, 16);
    cfg.population.store_budget_bytes = 2 << 20;
    assert_eq!(cfg.num_clients, 100_000);
    assert_eq!(cfg.cohort_size(), 10_000);

    let mut exp = Experiment::build(&cfg).unwrap();
    assert!(exp.population().is_lazy());
    for round in 1..=cfg.rounds {
        let rec = exp.step(round).unwrap();
        assert!(rec.arrived > 0, "round {round}");
        assert!(rec.train_loss.is_finite());
        let resident = exp.population().store().resident_bytes();
        assert!(
            resident <= cfg.population.store_budget_bytes,
            "round {round}: resident {resident} > budget {}",
            cfg.population.store_budget_bytes
        );
    }
    // The cohort outgrew the budget: most of it was paged out.
    assert!(
        exp.population().store().spilled_len() > 5_000,
        "spilled only {}",
        exp.population().store().spilled_len()
    );
    assert!(exp.global.iter().all(|v| v.is_finite()));
}
