//! Scheduler integration tests: the `sync` policy through the
//! event-driven engine must be BIT-IDENTICAL to the retained serial
//! reference loop, and the straggler-tolerant policies must buy real
//! simulated wall-clock on straggler-heavy links without giving up the
//! target accuracy.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::{run_experiment, Experiment};
use afd::metrics::RoundRecord;
use afd::network::LinkConfig;

fn assert_bit_identical(a: &RoundRecord, b: &RoundRecord) {
    assert_eq!(a.round, b.round);
    assert_eq!(
        a.round_s.to_bits(),
        b.round_s.to_bits(),
        "round {}: round_s {} vs {}",
        a.round,
        a.round_s,
        b.round_s
    );
    assert_eq!(a.cum_s.to_bits(), b.cum_s.to_bits(), "round {}", a.round);
    assert_eq!(
        a.train_loss.to_bits(),
        b.train_loss.to_bits(),
        "round {}: loss {} vs {}",
        a.round,
        a.train_loss,
        b.train_loss
    );
    assert_eq!(
        a.eval_acc.map(f64::to_bits),
        b.eval_acc.map(f64::to_bits),
        "round {}",
        a.round
    );
    assert_eq!(a.eval_loss.map(f64::to_bits), b.eval_loss.map(f64::to_bits));
    assert_eq!(a.down_bytes, b.down_bytes, "round {}", a.round);
    assert_eq!(a.up_bytes, b.up_bytes, "round {}", a.round);
    assert_eq!(
        a.down_payload_bytes, b.down_payload_bytes,
        "round {}",
        a.round
    );
    assert_eq!(a.up_payload_bytes, b.up_payload_bytes, "round {}", a.round);
    assert_eq!(
        a.keep_fraction.to_bits(),
        b.keep_fraction.to_bits(),
        "round {}",
        a.round
    );
    assert_eq!(a.arrived, b.arrived, "round {}", a.round);
    assert_eq!(a.cut, b.cut);
    assert_eq!(a.dropped, b.dropped);
}

/// The acceptance bar for the engine rewrite: `Sync` through the
/// event loop (with parallel client execution and sharded
/// aggregation) reproduces the serial reference byte-for-byte —
/// losses, bytes, simulated times — with and without DGC on the
/// uplink, across dropout strategies, seeds, and shard counts
/// (0 = auto; explicit counts force multi-shard fan-out on the small
/// native model, including a count above the worker-pool width).
#[test]
fn sync_engine_is_bit_identical_to_serial_reference() {
    for (uplink_dgc, dropout, seed, shards) in [
        (true, "afd_multi", 0u64, 0usize),
        (true, "afd_single", 3, 4),
        (false, "afd_multi", 0, 7),
        (false, "none", 7, 1),
        (true, "fd", 11, 13),
    ] {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 8;
        cfg.eval_every = 2;
        cfg.uplink_dgc = uplink_dgc;
        cfg.dropout = dropout.into();
        cfg.seed = seed;
        cfg.sharding.shard_count = shards;
        assert_eq!(cfg.sched.policy, "sync");

        let mut engine = Experiment::build(&cfg).unwrap();
        let mut serial = Experiment::build(&cfg).unwrap();
        for round in 1..=cfg.rounds {
            let a = engine.step(round).unwrap();
            let b = serial.step_serial_reference(round).unwrap();
            assert_bit_identical(&a, &b);
        }
        // The global models themselves must agree bitwise too.
        assert_eq!(engine.global.len(), serial.global.len());
        for (x, y) in engine.global.iter().zip(&serial.global) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "dgc={uplink_dgc} {dropout} seed {seed} shards {shards}"
            );
        }
    }
}

/// Sharded aggregation must be invisible in every record: the same run
/// at shard counts 1 and 7 is bit-identical, for every policy
/// (AsyncBuffered exercises staleness-discounted non-unit aggregation
/// weights through the sharded adds).
#[test]
fn every_policy_is_shard_count_invariant() {
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 6;
        cfg.eval_every = 3;
        cfg.link = LinkConfig::straggler_heavy();
        cfg.sched.policy = policy.into();
        cfg.sched.buffer_k = 2; // async: small buffers ⇒ staleness > 0
        let mut one = cfg.clone();
        one.sharding.shard_count = 1;
        let mut many = cfg.clone();
        many.sharding.shard_count = 7;
        let a = run_experiment(&one).unwrap();
        let b = run_experiment(&many).unwrap();
        assert_eq!(a.records.len(), b.records.len(), "{policy}");
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_bit_identical(x, y);
        }
    }
}

/// Staleness-weighting regression under sharding: with buffered async
/// aggregation, the `1/(1+staleness)^α` discount must actually flow
/// through the sharded adds — cranking α must change the trajectory,
/// and each α must stay shard-count invariant.
#[test]
fn async_staleness_weighting_survives_sharding() {
    let base = {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 12;
        cfg.eval_every = 3;
        cfg.link = LinkConfig::straggler_heavy();
        cfg.sched.policy = "async_buffered".into();
        cfg.sched.buffer_k = 2; // aggregate every 2 arrivals ⇒ frequent
        cfg.sharding.shard_count = 6; // stale merges under sharding
        cfg
    };
    let mut flat = base.clone();
    flat.sched.staleness_alpha = 0.0; // discount off: all weights 1
    let mut heavy = base.clone();
    heavy.sched.staleness_alpha = 4.0; // aggressive discount

    let r_flat = run_experiment(&flat).unwrap();
    let r_heavy = run_experiment(&heavy).unwrap();
    assert!(
        r_flat
            .records
            .iter()
            .zip(&r_heavy.records)
            .any(|(x, y)| x.train_loss.to_bits() != y.train_loss.to_bits()
                || x.eval_acc.map(f64::to_bits) != y.eval_acc.map(f64::to_bits)),
        "staleness discount must influence sharded aggregation"
    );
    // And the discounted run itself is reproducible and shard-count
    // invariant (non-unit weights take the same per-coordinate path).
    let mut heavy_one = heavy.clone();
    heavy_one.sharding.shard_count = 1;
    let r_heavy_one = run_experiment(&heavy_one).unwrap();
    for (x, y) in r_heavy.records.iter().zip(&r_heavy_one.records) {
        assert_bit_identical(x, y);
    }
}

/// Scheduler runs must be reproducible run-to-run for every policy
/// (parallel execution must not leak nondeterminism into records).
#[test]
fn every_policy_is_deterministic_across_runs() {
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 6;
        cfg.eval_every = 3;
        cfg.sched.policy = policy.into();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_bit_identical(x, y);
        }
    }
}

fn straggler_cfg(policy: &str, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 60;
    cfg.eval_every = 2;
    cfg.seed = seed;
    cfg.link = LinkConfig::straggler_heavy();
    cfg.sched.policy = policy.into();
    cfg
}

/// The point of the subsystem: under straggler-heavy links, both
/// overselection and buffered asynchrony reach the NativeSmoke target
/// accuracy in measurably less simulated wall-clock than synchronous
/// FedAvg. Summed over two seeds so a single lucky cohort draw cannot
/// flip the ordering.
#[test]
fn straggler_policies_reach_target_accuracy_faster_than_sync() {
    let target = 0.45;
    let mut t_sync = 0.0;
    let mut t_over = 0.0;
    let mut t_async = 0.0;
    for seed in [0u64, 1] {
        let sync = run_experiment(&straggler_cfg("sync", seed)).unwrap();
        let over = run_experiment(&straggler_cfg("overselect", seed)).unwrap();
        let asyn = run_experiment(&straggler_cfg("async_buffered", seed)).unwrap();
        t_sync += sync
            .time_to_accuracy(target, 1)
            .unwrap_or_else(|| panic!("sync seed {seed} best {}", sync.best_accuracy()))
            .1;
        t_over += over
            .time_to_accuracy(target, 1)
            .unwrap_or_else(|| panic!("overselect seed {seed} best {}", over.best_accuracy()))
            .1;
        t_async += asyn
            .time_to_accuracy(target, 1)
            .unwrap_or_else(|| {
                panic!("async seed {seed} best {}", asyn.best_accuracy())
            })
            .1;
    }
    assert!(
        t_over < t_sync,
        "overselect must beat sync to {target}: {t_over:.1}s vs {t_sync:.1}s"
    );
    assert!(
        t_async < t_sync,
        "async_buffered must beat sync to {target}: {t_async:.1}s vs {t_sync:.1}s"
    );
}

/// Overselect semantics: stragglers are cut (recorded per round) and
/// their bytes are not charged — per-round downlink traffic can never
/// exceed the aggregated cohort's worth.
#[test]
fn overselect_cuts_stragglers_and_charges_only_arrivals() {
    let cfg = straggler_cfg("overselect", 0);
    let m = cfg.cohort_size();
    let r = run_experiment(&cfg).unwrap();
    let total_cut: usize = r.records.iter().map(|rec| rec.cut).sum();
    assert!(total_cut > 0, "straggler-heavy links must cut someone");
    for rec in &r.records {
        assert!(rec.arrived <= m, "round {}: {} > m", rec.round, rec.arrived);
        assert!(rec.arrived > 0);
    }
    // Sync on the same links pays for the full dispatch width each
    // round; overselect charges only arrivals, so its mean per-round
    // traffic cannot exceed sync's.
    let sync = run_experiment(&straggler_cfg("sync", 0)).unwrap();
    let over_down: u64 = r.records.iter().map(|x| x.down_bytes).sum();
    let sync_down: u64 = sync.records.iter().map(|x| x.down_bytes).sum();
    assert!(over_down <= sync_down + sync_down / 10);
}

/// Async mechanics: aggregations happen every K arrivals, slow clients
/// never gate cadence, and the staleness discount keeps the run
/// learning.
#[test]
fn async_buffered_aggregates_small_buffers_and_learns() {
    let mut cfg = straggler_cfg("async_buffered", 0);
    cfg.sched.buffer_k = 3;
    let r = run_experiment(&cfg).unwrap();
    for rec in &r.records {
        assert!(
            rec.arrived <= 3,
            "round {}: buffer overflow {}",
            rec.round,
            rec.arrived
        );
    }
    assert!(r.best_accuracy() > 0.4, "async must learn: {}", r.best_accuracy());
}
