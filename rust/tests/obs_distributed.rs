//! Distributed telemetry conformance: shipping telemetry must be
//! *invisible* in results, and the merge must be real.
//!
//! Two contracts on top of `obs_conformance.rs`:
//!
//! 1. **Bit-identity with the side channel live** — a fixed-seed run
//!    with telemetry armed (loopback mirror, or real `Telemetry`
//!    frames over in-process TCP) produces byte-identical per-round
//!    JSONL records and final model hash to a telemetry-off run, for
//!    every scheduler policy. Telemetry bytes land in
//!    `TELEMETRY_BYTES`, never in `RoundRecord` accounting.
//! 2. **The merged timeline is well-formed** — after a traced TCP run
//!    the Chrome trace carries one named process track per remote
//!    client process (distinct pids, all different from the
//!    coordinator's), remote spans ride those pids with non-negative
//!    clock-aligned timestamps, and the embedded stats dump reports
//!    per-process frame/span/counter totals.
//!
//! The enable flag, metrics registry and remote-process registry are
//! process-global, so every test here serializes on one mutex.

use std::sync::{Arc, Mutex, MutexGuard};

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::runtime::native::mlp_from_config;
use afd::transport::tcp::{run_client_loop, ClientOptions, TcpServer};
use afd::transport::Transport;
use afd::util::model_hash;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn smoke_cfg(policy: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 5;
    cfg.eval_every = 2;
    cfg.uplink_dgc = true;
    cfg.sched.policy = policy.into();
    cfg
}

/// Run over the loopback transport, returning each round's record
/// exactly as the CLI would serialize it, plus the final model hash.
fn run_loopback(cfg: &ExperimentConfig) -> (Vec<String>, u64) {
    let mut exp = Experiment::build(cfg).unwrap();
    let mut lines = Vec::with_capacity(cfg.rounds);
    for round in 1..=cfg.rounds {
        lines.push(exp.step(round).unwrap().to_json().to_string_compact());
    }
    (lines, model_hash(&exp.global))
}

/// Run over real sockets: in-process client threads driving the actual
/// `afd client` loop against an ephemeral-port server.
fn run_tcp(cfg: &ExperimentConfig, conns: usize) -> (Vec<String>, u64) {
    let (_, spec) = mlp_from_config(cfg);
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handles: Vec<_> = (0..conns)
        .map(|_| {
            let a = addr.clone();
            let opts = ClientOptions {
                connect_retry_s: 10.0,
                ..ClientOptions::default()
            };
            std::thread::spawn(move || run_client_loop(&a, &opts))
        })
        .collect();
    let transport = server
        .accept_clients(
            conns,
            &cfg.to_json().to_string_compact(),
            spec.layout_fingerprint(),
            &cfg.transport,
        )
        .unwrap();
    let transport: Arc<dyn Transport> = Arc::new(transport);
    let mut exp = Experiment::build_with_transport(cfg, Arc::clone(&transport)).unwrap();
    let mut lines = Vec::with_capacity(cfg.rounds);
    for round in 1..=cfg.rounds {
        lines.push(exp.step(round).unwrap().to_json().to_string_compact());
    }
    let hash = model_hash(&exp.global);
    transport.shutdown().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    (lines, hash)
}

fn assert_identical(plain: &(Vec<String>, u64), armed: &(Vec<String>, u64), what: &str) {
    assert_eq!(plain.0.len(), armed.0.len(), "{what}: round count diverged");
    for (a, b) in plain.0.iter().zip(&armed.0) {
        assert_eq!(a, b, "{what}: a round record diverged under telemetry");
    }
    assert_eq!(
        plain.1, armed.1,
        "{what}: final model hash diverged under telemetry"
    );
}

/// The loopback transport mirrors the full telemetry path in-process
/// (encode → parse → merge) when tracing is live; the mirror must not
/// perturb a single byte of the results.
#[test]
fn telemetry_mirror_keeps_loopback_runs_bit_identical() {
    let _s = serial();
    for policy in ["sync", "overselect", "async_buffered"] {
        let cfg = smoke_cfg(policy);

        afd::obs::reset();
        afd::obs::set_enabled(false);
        let plain = run_loopback(&cfg);

        afd::obs::reset();
        afd::obs::set_enabled(true);
        let armed = run_loopback(&cfg);
        let was_live = afd::obs::enabled();
        afd::obs::set_enabled(false);

        assert_identical(&plain, &armed, policy);

        if was_live {
            // The mirror really ran: telemetry frames were encoded,
            // parsed and merged under the "loopback" process name, and
            // their bytes were accounted on the side channel.
            assert!(
                afd::obs::metrics::TELEMETRY_FRAMES.get() >= cfg.rounds as u64,
                "{policy}: loopback mirror shipped no telemetry frames"
            );
            assert!(
                afd::obs::metrics::TELEMETRY_BYTES.get() > 0,
                "{policy}: telemetry bytes not accounted"
            );
            let stats = afd::obs::export::stats_json();
            let rem = stats.get("remote").unwrap().get("loopback").unwrap();
            assert!(
                rem.get("frames").and_then(|f| f.as_f64()).unwrap_or(0.0)
                    >= cfg.rounds as f64,
                "{policy}: loopback proc missing from merged stats"
            );
        }
    }
}

/// Real `Telemetry` frames over real sockets: piggybacked after
/// `UpdateUp`, consumed by the coordinator without entering the
/// round's FIFO, merged into per-process tracks — and still invisible
/// in the results.
#[test]
fn telemetry_shipping_keeps_tcp_runs_bit_identical_for_every_policy() {
    let _s = serial();
    for policy in ["sync", "overselect", "async_buffered"] {
        let cfg = smoke_cfg(policy);

        afd::obs::reset();
        afd::obs::set_enabled(false);
        let plain = run_tcp(&cfg, 2);

        afd::obs::reset();
        afd::obs::set_enabled(true);
        let armed = run_tcp(&cfg, 2);
        let was_live = afd::obs::enabled();
        afd::obs::set_enabled(false);

        assert_identical(&plain, &armed, policy);

        if was_live {
            assert!(
                afd::obs::metrics::TELEMETRY_FRAMES.get() > 0,
                "{policy}: no telemetry frames arrived over TCP"
            );
            assert!(
                afd::obs::metrics::TELEMETRY_BYTES.get() > 0,
                "{policy}: telemetry wire bytes not accounted"
            );
        }
    }
}

/// After a traced TCP run the merged Chrome trace must hold one named
/// process group per remote client process with clock-aligned spans,
/// and the stats dump must carry per-process totals.
#[test]
fn merged_trace_has_a_named_clock_aligned_track_per_remote_process() {
    let _s = serial();
    let cfg = smoke_cfg("sync");

    afd::obs::reset();
    afd::obs::set_enabled(true);
    let _ = run_tcp(&cfg, 2);
    let was_live = afd::obs::enabled();
    afd::obs::set_enabled(false);
    if !was_live {
        return; // probes compiled out (--no-default-features)
    }

    let doc = afd::obs::export::chrome_trace_json();
    let text = doc.to_string_compact();
    let back = afd::util::json::parse(&text).unwrap();
    let events = back.get("traceEvents").unwrap().as_arr().unwrap();

    // Every remote client process got its own named pid, distinct from
    // the coordinator's and from each other.
    let mut proc_pids: Vec<(u64, String)> = Vec::new();
    for e in events {
        if e.get("name").and_then(|n| n.as_str()) == Some("process_name") {
            let pid = e.get("pid").and_then(|p| p.as_f64()).unwrap() as u64;
            let name = e
                .get("args")
                .and_then(|a| a.get("name"))
                .and_then(|n| n.as_str())
                .unwrap()
                .to_string();
            proc_pids.push((pid, name));
        }
    }
    let coord_pid = afd::obs::remote::COORDINATOR_PID as u64;
    assert!(
        proc_pids.iter().any(|(p, _)| *p == coord_pid),
        "coordinator process track missing"
    );
    let remote_tracks: Vec<&(u64, String)> =
        proc_pids.iter().filter(|(p, _)| *p != coord_pid).collect();
    assert!(
        remote_tracks.len() >= 2,
        "expected both client processes as tracks, got {proc_pids:?}"
    );
    for w in 0..remote_tracks.len() {
        for v in (w + 1)..remote_tracks.len() {
            assert_ne!(
                remote_tracks[w].0, remote_tracks[v].0,
                "remote processes share a pid: {proc_pids:?}"
            );
        }
    }
    assert!(
        remote_tracks
            .iter()
            .any(|(_, n)| n.starts_with("client-slot-")),
        "remote tracks not named by slot: {proc_pids:?}"
    );

    // Remote spans ride remote pids with sane aligned clocks, and
    // every span track inside those pids is named.
    let mut remote_spans = 0usize;
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str());
        if ph != Some("X") {
            continue;
        }
        let ts = e.get("ts").and_then(|t| t.as_f64()).unwrap();
        let dur = e.get("dur").and_then(|d| d.as_f64()).unwrap();
        assert!(ts >= 0.0 && dur >= 0.0, "negative clock in merged trace");
        if e.get("pid").and_then(|p| p.as_f64()).unwrap() as u64 != coord_pid {
            remote_spans += 1;
        }
    }
    assert!(remote_spans > 0, "no spans merged from remote processes");

    // The embedded stats dump mirrors the same merge.
    let stats = back.get("afd_stats").unwrap();
    let rem = stats.get("remote").unwrap().as_obj().unwrap();
    let slots: Vec<&String> = rem
        .iter()
        .map(|(k, _)| k)
        .filter(|k| k.starts_with("client-slot-"))
        .collect();
    assert!(
        slots.len() >= 2,
        "stats dump missing remote client processes: {slots:?}"
    );
    for (name, r) in rem.iter() {
        assert!(
            r.get("frames").and_then(|f| f.as_f64()).unwrap_or(0.0) > 0.0,
            "{name}: merged zero telemetry frames"
        );
        assert!(
            r.get("counters")
                .and_then(|c| c.as_obj())
                .map(|c| !c.is_empty())
                .unwrap_or(false),
            "{name}: no counter totals shipped"
        );
    }
}
