//! Data-pipeline integration on the real artifact specs: generators must
//! produce model-consumable, learnable, heterogeneity-controlled data.

use afd::data::{generate, DataConfig, Samples};
use afd::model::manifest::{DType, Manifest};
use afd::util::rng::Pcg64;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).unwrap())
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn generators_match_every_variant_spec() {
    let Some(man) = manifest() else { return };
    for spec in man.variants.values() {
        let cfg = DataConfig {
            num_clients: 10,
            samples_per_client: (30, 60),
            iid: false,
            test_fraction: 0.2,
            seed: 3,
        };
        let ds = generate(spec, &cfg);
        assert_eq!(ds.num_clients(), 10, "{}", spec.name);
        let per: usize = spec.input_shape.iter().product();
        for c in &ds.clients {
            assert_eq!(c.per_sample, per, "{}", spec.name);
            assert!(c.ys.iter().all(|&y| (y as usize) < spec.classes));
            match (&c.xs, spec.input_dtype) {
                (Samples::F32(v), DType::F32) => assert_eq!(v.len(), c.len() * per),
                (Samples::I32(v), DType::I32) => {
                    assert_eq!(v.len(), c.len() * per);
                    assert!(v.iter().all(|&t| (t as usize) < spec.vocab.max(53)));
                }
                _ => panic!("{}: dtype mismatch", spec.name),
            }
        }
        assert!(!ds.test.is_empty());
    }
}

#[test]
fn epoch_data_feeds_runtime_shapes() {
    let Some(man) = manifest() else { return };
    for spec in man.variants.values() {
        let cfg = DataConfig {
            num_clients: 4,
            samples_per_client: (20, 40),
            iid: true,
            test_fraction: 0.2,
            seed: 5,
        };
        let ds = generate(spec, &cfg);
        let mut rng = Pcg64::new(0);
        let ep = ds.clients[0].epoch_data(spec, &mut rng);
        afd::runtime::check_epoch_data(spec, &ep)
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let batches = ds.test.eval_batches(spec, Some(3));
        for b in &batches {
            afd::runtime::check_eval_batch(spec, b)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        }
    }
}

#[test]
fn noniid_is_more_heterogeneous_than_iid() {
    // Label-distribution spread across clients must be measurably larger
    // in the non-IID split for every dataset family.
    let Some(man) = manifest() else { return };
    for spec in man.variants.values() {
        let spread = |iid: bool| -> f64 {
            let cfg = DataConfig {
                num_clients: 12,
                samples_per_client: (60, 60),
                iid,
                test_fraction: 0.0,
                seed: 11,
            };
            let ds = generate(spec, &cfg);
            // Mean total-variation distance of each client's histogram
            // from the global one. For sequence data (many tokens per
            // client) we histogram input tokens — labels over 53 classes
            // with ~50 samples are sampling-noise dominated; tokens give
            // ~1000s of observations per client.
            let per_client_hist: Vec<Vec<f64>> = ds
                .clients
                .iter()
                .map(|c| match &c.xs {
                    Samples::I32(v) if c.per_sample > 1 => {
                        let k = spec.vocab.max(spec.classes);
                        let mut h = vec![0.0f64; k];
                        for &t in v {
                            h[t as usize] += 1.0;
                        }
                        h
                    }
                    _ => {
                        let mut h = vec![0.0f64; spec.classes];
                        for &y in &c.ys {
                            h[y as usize] += 1.0;
                        }
                        h
                    }
                })
                .collect();
            let k = per_client_hist[0].len();
            let mut global = vec![0.0f64; k];
            for h in &per_client_hist {
                for (g, v) in global.iter_mut().zip(h) {
                    *g += v;
                }
            }
            let gt: f64 = global.iter().sum();
            for g in &mut global {
                *g /= gt;
            }
            let mut tv = 0.0;
            for h in &per_client_hist {
                let t: f64 = h.iter().sum();
                tv += h
                    .iter()
                    .zip(&global)
                    .map(|(a, b)| (a / t - b).abs())
                    .sum::<f64>()
                    / 2.0;
            }
            tv / ds.clients.len() as f64
        };
        let tv_noniid = spread(false);
        let tv_iid = spread(true);
        assert!(
            tv_noniid > tv_iid * 1.3,
            "{}: non-IID TV {tv_noniid:.3} vs IID {tv_iid:.3}",
            spec.name
        );
    }
}

#[test]
fn femnist_is_learnable_through_pjrt() {
    // The synthetic glyphs must actually be learnable by the CNN
    // artifact: a few epochs of central training on pooled data should
    // beat random-guess accuracy by a wide margin.
    let Some(man) = manifest() else { return };
    use afd::runtime::{pjrt::PjrtRuntime, ModelRuntime};
    let spec = man.variant("femnist_small").unwrap().clone();
    let client = xla::PjRtClient::cpu().unwrap();
    let rt = PjrtRuntime::load(&client, &man, "femnist_small").unwrap();
    let mut params = man.load_init_params(&spec).unwrap();

    let cfg = DataConfig {
        num_clients: 4,
        samples_per_client: (80, 80),
        iid: true,
        test_fraction: 0.25,
        seed: 21,
    };
    let ds = generate(&spec, &cfg);
    let masks: Vec<Vec<f32>> = spec
        .mask_groups
        .iter()
        .map(|g| vec![1.0; g.size])
        .collect();
    let mut rng = Pcg64::new(1);
    for _epoch in 0..6 {
        for c in &ds.clients {
            let ep = c.epoch_data(&spec, &mut rng);
            let out = rt.train_epoch(&params, &masks, &ep, spec.lr).unwrap();
            params = out.params;
        }
    }
    let mut total = afd::runtime::EvalOutput::default();
    for b in ds.test.eval_batches(&spec, Some(8)) {
        total.merge(&rt.evaluate(&params, &b).unwrap());
    }
    let acc = total.accuracy();
    let chance = 1.0 / spec.classes as f64;
    assert!(
        acc > chance * 3.0,
        "synthetic femnist should be learnable: acc {acc:.3} (chance {chance:.3})"
    );
}
