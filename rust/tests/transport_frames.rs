//! Frame robustness: every malformed input — truncation at any byte,
//! any single-bit flip, version mismatches, oversized length prefixes,
//! garbage — must decode to a diagnosable [`FrameError`], never a
//! panic and never an unbounded loop. Well-formed frames round-trip
//! every message type bit-exactly.

use afd::model::submodel::SubModel;
use afd::prop::UsizeIn;
use afd::transport::frame::{self, FrameError, FrameKind};
use afd::util::rng::Pcg64;

fn sample_submodel(rng: &mut Pcg64, groups: usize, max_units: usize) -> SubModel {
    let keep = (0..groups)
        .map(|_| {
            let n = 1 + rng.below(max_units as u64) as usize;
            (0..n).map(|_| rng.next_f64() < 0.6).collect()
        })
        .collect();
    SubModel::from_keep(keep)
}

/// A corpus covering every frame kind with varied payload sizes.
fn frame_corpus(seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg64::new(seed);
    let mut frames = Vec::new();
    let mut buf = Vec::new();

    frame::encode_hello(&mut buf);
    frames.push(std::mem::take(&mut buf));
    frame::encode_ready(&mut buf, rng.next_u64());
    frames.push(std::mem::take(&mut buf));
    frame::encode_bye(&mut buf);
    frames.push(std::mem::take(&mut buf));
    frame::encode_config(&mut buf, rng.next_u64(), "{\"rounds\": 3}");
    frames.push(std::mem::take(&mut buf));
    frame::encode_round_close(&mut buf, true, 7, 3);
    frames.push(std::mem::take(&mut buf));
    frame::encode_round_close(&mut buf, false, 8, 4);
    frames.push(std::mem::take(&mut buf));

    for i in 0..6 {
        let sm = sample_submodel(&mut rng, 1 + (i % 3), 40);
        frame::encode_round_offer(
            &mut buf,
            i as u32,
            rng.below(100) as u32,
            rng.next_u64(),
            0.1,
            if i % 2 == 0 { f64::NAN } else { 12.5 },
            &sm,
        );
        frames.push(std::mem::take(&mut buf));

        let payload: Vec<u8> = (0..rng.below(300)).map(|_| rng.next_u64() as u8).collect();
        frame::encode_model_down(&mut buf, i as u32, i as u32, 1, &payload);
        frames.push(std::mem::take(&mut buf));

        let base = frame::begin_update_up(&mut buf, i as u32, i as u32, 50, 0.3, frame::UPDATE_DGC);
        buf.extend((0..rng.below(200)).map(|_| rng.next_u64() as u8));
        frame::end_frame(&mut buf, base);
        frames.push(std::mem::take(&mut buf));
    }
    frames
}

#[test]
fn well_formed_frames_parse_and_roundtrip() {
    for f in frame_corpus(1) {
        let (view, used) = frame::parse_frame(&f).expect("well-formed frame must parse");
        assert_eq!(used, f.len());
        assert_eq!(
            f.len() as u64,
            frame::FRAME_OVERHEAD + view.payload.len() as u64
        );
    }
}

#[test]
fn round_offer_roundtrips_submodel_exactly() {
    let mut rng = Pcg64::new(2);
    for case in 0..30 {
        let sm = sample_submodel(&mut rng, 1 + (case % 4), 70);
        let mut buf = Vec::new();
        frame::encode_round_offer(&mut buf, case as u32, 9, 0xdead_beef, 0.25, f64::NAN, &sm);
        let (view, _) = frame::parse_frame(&buf).unwrap();
        let offer = frame::parse_round_offer(&view).unwrap();
        assert_eq!(offer.round, case as u32);
        assert_eq!(offer.client, 9);
        assert_eq!(offer.seed, 0xdead_beef);
        assert_eq!(offer.lr, 0.25);
        assert!(offer.deadline_s.is_nan());
        assert!(offer.matches_submodel(&sm), "case {case}");
        assert_eq!(offer.submodel().keep, sm.keep, "case {case}");
        // A flipped unit must no longer match.
        let mut other = sm.keep.clone();
        other[0][0] = !other[0][0];
        assert!(!offer.matches_submodel(&SubModel::from_keep(other)));
    }
}

#[test]
fn update_up_roundtrips_fields() {
    let mut buf = Vec::new();
    let body = [1u8, 2, 3, 4, 5];
    let base = frame::begin_update_up(&mut buf, 11, 4, 123, -0.75, frame::UPDATE_RAW);
    buf.extend_from_slice(&body);
    frame::end_frame(&mut buf, base);
    let (view, _) = frame::parse_frame(&buf).unwrap();
    let upd = frame::parse_update_up(&view).unwrap();
    assert_eq!(
        (upd.round, upd.client, upd.samples, upd.update_kind),
        (11, 4, 123, frame::UPDATE_RAW)
    );
    assert_eq!(upd.loss, -0.75);
    assert_eq!(upd.payload, body);
}

/// Truncation at EVERY prefix length must be a `FrameError` (almost
/// always `Truncated`; a cut inside the header can also surface as a
/// magic/version error on garbage) — never a panic.
#[test]
fn truncation_at_every_byte_is_an_error() {
    for f in frame_corpus(3) {
        for cut in 0..f.len() {
            let r = frame::parse_frame(&f[..cut]);
            assert!(r.is_err(), "prefix of {cut}/{} bytes parsed", f.len());
        }
    }
}

/// CRC-32 detects every single-bit error, and the length/magic/version
/// checks cover the prefix fields — so flipping ANY single bit of a
/// valid frame must yield an error, never a panic and never a clean
/// parse.
#[test]
fn any_single_bit_flip_is_detected() {
    for f in frame_corpus(4) {
        for byte in 0..f.len() {
            for bit in 0..8u8 {
                let mut corrupt = f.clone();
                corrupt[byte] ^= 1 << bit;
                let r = frame::parse_frame(&corrupt);
                assert!(
                    r.is_err(),
                    "flip byte {byte} bit {bit} of a {}-byte frame parsed cleanly",
                    f.len()
                );
            }
        }
    }
}

/// Random garbage (arbitrary bytes, arbitrary lengths) never panics
/// the parser.
#[test]
fn random_garbage_never_panics() {
    let gen = UsizeIn(0, 4096);
    afd::prop::check("garbage frames", &gen, 60, |&n| {
        let mut rng = Pcg64::new(n as u64 + 99);
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        // Any Result is fine — the property is "no panic, no hang".
        let _ = frame::parse_frame(&bytes);
        // Also exercise the typed parsers on whatever view survives.
        if let Ok((view, _)) = frame::parse_frame(&bytes) {
            let _ = frame::parse_round_offer(&view);
            let _ = frame::parse_update_up(&view);
            let _ = frame::parse_model_down(&view);
            let _ = frame::parse_round_close(&view);
            let _ = frame::parse_config(&view);
            let _ = frame::parse_ready(&view);
        }
        Ok(())
    });
}

/// Payload-level malformation (valid frame envelope, short payload)
/// errors with the field name, never panics.
#[test]
fn short_payloads_error_diagnosably() {
    // An Ack frame whose payload is 3 bytes instead of 8.
    let mut buf = Vec::new();
    let base = frame::begin_frame(&mut buf, FrameKind::Ack);
    buf.extend_from_slice(&[1, 2, 3]);
    frame::end_frame(&mut buf, base);
    let (view, _) = frame::parse_frame(&buf).unwrap();
    match frame::parse_round_close(&view) {
        Err(FrameError::BadPayload { kind, .. }) => assert_eq!(kind, FrameKind::Ack),
        other => panic!("want BadPayload, got {other:?}"),
    }

    // A RoundOffer whose group region is cut mid-bitmap.
    let sm = SubModel::from_keep(vec![vec![true; 20]]);
    let mut full = Vec::new();
    frame::encode_round_offer(&mut full, 1, 2, 3, 0.1, f64::NAN, &sm);
    let (view, _) = frame::parse_frame(&full).unwrap();
    let payload = view.payload;
    let mut cut = Vec::new();
    let base = frame::begin_frame(&mut cut, FrameKind::RoundOffer);
    cut.extend_from_slice(&payload[..payload.len() - 1]);
    frame::end_frame(&mut cut, base);
    let (view, _) = frame::parse_frame(&cut).unwrap();
    assert!(matches!(
        frame::parse_round_offer(&view),
        Err(FrameError::BadPayload { .. })
    ));
}

#[test]
fn wrong_kind_routing_is_an_error() {
    let mut buf = Vec::new();
    frame::encode_hello(&mut buf);
    let (view, _) = frame::parse_frame(&buf).unwrap();
    assert!(frame::parse_round_offer(&view).is_err());
    assert!(frame::parse_update_up(&view).is_err());
    assert!(frame::parse_model_down(&view).is_err());
    assert!(frame::parse_config(&view).is_err());
}
