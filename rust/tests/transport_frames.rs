//! Frame robustness: every malformed input — truncation at any byte,
//! any single-bit flip, version mismatches, oversized length prefixes,
//! garbage — must decode to a diagnosable [`FrameError`], never a
//! panic and never an unbounded loop. Well-formed frames round-trip
//! every message type bit-exactly.

use afd::model::submodel::SubModel;
use afd::prop::UsizeIn;
use afd::transport::frame::{self, FrameError, FrameKind};
use afd::util::rng::Pcg64;

fn sample_submodel(rng: &mut Pcg64, groups: usize, max_units: usize) -> SubModel {
    let keep = (0..groups)
        .map(|_| {
            let n = 1 + rng.below(max_units as u64) as usize;
            (0..n).map(|_| rng.next_f64() < 0.6).collect()
        })
        .collect();
    SubModel::from_keep(keep)
}

/// Run-structured masks: long kept/dropped stretches, the shape the
/// RLE group encoding exists for.
fn runny_submodel(rng: &mut Pcg64, groups: usize, max_units: usize) -> SubModel {
    let keep = (0..groups)
        .map(|_| {
            let n = 1 + rng.below(max_units as u64) as usize;
            let mut bits = Vec::with_capacity(n);
            let mut cur = rng.next_f64() < 0.5;
            while bits.len() < n {
                let run = 1 + rng.below(48) as usize;
                for _ in 0..run.min(n - bits.len()) {
                    bits.push(cur);
                }
                cur = !cur;
            }
            bits
        })
        .collect();
    SubModel::from_keep(keep)
}

/// A corpus covering every frame kind with varied payload sizes.
fn frame_corpus(seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg64::new(seed);
    let mut frames = Vec::new();
    let mut buf = Vec::new();

    frame::encode_hello(&mut buf, 0);
    frames.push(std::mem::take(&mut buf));
    frame::encode_hello(&mut buf, rng.next_u64());
    frames.push(std::mem::take(&mut buf));
    frame::encode_ready(&mut buf, rng.next_u64(), rng.next_u64());
    frames.push(std::mem::take(&mut buf));
    frame::encode_bye(&mut buf);
    frames.push(std::mem::take(&mut buf));
    frame::encode_config(&mut buf, rng.next_u64(), rng.below(9), "{\"rounds\": 3}");
    frames.push(std::mem::take(&mut buf));
    frame::encode_state_sync(&mut buf, 3, 17, rng.next_u64() as u128, rng.next_u64() as u128, &[], &[]);
    frames.push(std::mem::take(&mut buf));
    let res: Vec<f32> = (0..33).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    frame::encode_state_sync(
        &mut buf,
        9,
        1 << 40,
        u128::MAX,
        (1u128 << 64) | 7,
        &res,
        &res,
    );
    frames.push(std::mem::take(&mut buf));
    frame::encode_round_close(&mut buf, true, 7, 3);
    frames.push(std::mem::take(&mut buf));
    frame::encode_round_close(&mut buf, false, 8, 4);
    frames.push(std::mem::take(&mut buf));

    for i in 0..6 {
        let sm = sample_submodel(&mut rng, 1 + (i % 3), 40);
        frame::encode_round_offer(
            &mut buf,
            i as u32,
            rng.below(100) as u32,
            rng.next_u64(),
            0.1,
            if i % 2 == 0 { f64::NAN } else { 12.5 },
            &sm,
        );
        frames.push(std::mem::take(&mut buf));

        let payload: Vec<u8> = (0..rng.below(300)).map(|_| rng.next_u64() as u8).collect();
        frame::encode_model_down(&mut buf, i as u32, i as u32, 1, &payload);
        frames.push(std::mem::take(&mut buf));

        let base = frame::begin_update_up(&mut buf, i as u32, i as u32, 50, 0.3, frame::UPDATE_DGC);
        buf.extend((0..rng.below(200)).map(|_| rng.next_u64() as u8));
        frame::end_frame(&mut buf, base);
        frames.push(std::mem::take(&mut buf));
    }

    // Run-structured offers so the truncation / bit-flip sweeps cover
    // the RLE group encoding, not just raw bitmaps.
    for i in 0..4 {
        let sm = runny_submodel(&mut rng, 1 + (i % 2), 220);
        frame::encode_round_offer(&mut buf, 100 + i as u32, i as u32, 1, 0.05, f64::NAN, &sm);
        frames.push(std::mem::take(&mut buf));
    }
    let uniform = SubModel::from_keep(vec![vec![true; 200], vec![false; 177], vec![true; 64]]);
    frame::encode_round_offer(&mut buf, 200, 0, 2, 0.05, 1.0, &uniform);
    frames.push(std::mem::take(&mut buf));

    // Telemetry frames: empty (quiet process), and a few populated
    // ones so the truncation / bit-flip sweeps walk every section of
    // the schema (threads, spans, counters, gauges, histograms).
    {
        let mut enc = frame::TelemetryEncoder::begin(&mut buf, 0, rng.next_u64());
        enc.begin_threads();
        enc.end_threads();
        enc.begin_counters();
        enc.end_counters();
        enc.begin_gauges();
        enc.end_gauges();
        enc.begin_hists();
        enc.end_hists();
        enc.finish();
    }
    frames.push(std::mem::take(&mut buf));
    for case in 0..3u32 {
        let mut enc = frame::TelemetryEncoder::begin(&mut buf, 7 + case, rng.next_u64());
        enc.begin_threads();
        for t in 0..=case {
            enc.begin_thread(t, &format!("worker-{t}"), rng.below(5));
            for _ in 0..rng.below(6) {
                enc.span(
                    (rng.below(12) + 1) as u8,
                    rng.below(4) as u32,
                    rng.next_u64() >> 20,
                    rng.below(1 << 30),
                    rng.next_u64(),
                    rng.next_u64(),
                );
            }
        }
        enc.end_threads();
        enc.begin_counters();
        for id in 0..rng.below(8) as u8 {
            enc.counter(id, rng.below(1 << 40));
        }
        enc.end_counters();
        enc.begin_gauges();
        if case > 0 {
            enc.gauge(0, rng.next_u64());
        }
        enc.end_gauges();
        enc.begin_hists();
        for h in 0..rng.below(3) as u8 {
            enc.begin_hist(h + 1, 1 + rng.below(100), rng.below(1 << 40));
            enc.bucket((rng.below(30)) as u8, 1 + rng.below(50));
        }
        enc.end_hists();
        enc.finish();
        frames.push(std::mem::take(&mut buf));
    }

    frames
}

#[test]
fn well_formed_frames_parse_and_roundtrip() {
    for f in frame_corpus(1) {
        let (view, used) = frame::parse_frame(&f).expect("well-formed frame must parse");
        assert_eq!(used, f.len());
        assert_eq!(
            f.len() as u64,
            frame::FRAME_OVERHEAD + view.payload.len() as u64
        );
    }
}

#[test]
fn round_offer_roundtrips_submodel_exactly() {
    let mut rng = Pcg64::new(2);
    for case in 0..30 {
        let sm = sample_submodel(&mut rng, 1 + (case % 4), 70);
        let mut buf = Vec::new();
        frame::encode_round_offer(&mut buf, case as u32, 9, 0xdead_beef, 0.25, f64::NAN, &sm);
        let (view, _) = frame::parse_frame(&buf).unwrap();
        let offer = frame::parse_round_offer(&view).unwrap();
        assert_eq!(offer.round, case as u32);
        assert_eq!(offer.client, 9);
        assert_eq!(offer.seed, 0xdead_beef);
        assert_eq!(offer.lr, 0.25);
        assert!(offer.deadline_s.is_nan());
        assert!(offer.matches_submodel(&sm), "case {case}");
        assert_eq!(offer.submodel().keep, sm.keep, "case {case}");
        // A flipped unit must no longer match.
        let mut other = sm.keep.clone();
        other[0][0] = !other[0][0];
        assert!(!offer.matches_submodel(&SubModel::from_keep(other)));
    }
}

/// Run-structured and uniform masks round-trip exactly through the
/// RLE group encoding, and a long uniform run genuinely compresses:
/// the whole frame is smaller than the raw bitmap for the same mask
/// would be.
#[test]
fn rle_keep_masks_roundtrip_and_compress() {
    let mut rng = Pcg64::new(6);
    for case in 0..30 {
        let sm = runny_submodel(&mut rng, 1 + (case % 3), 300);
        let mut buf = Vec::new();
        frame::encode_round_offer(&mut buf, case as u32, 1, 7, 0.5, f64::NAN, &sm);
        let (view, _) = frame::parse_frame(&buf).unwrap();
        let offer = frame::parse_round_offer(&view).unwrap();
        assert_eq!(offer.submodel().keep, sm.keep, "case {case}");
        assert!(offer.matches_submodel(&sm), "case {case}");
    }

    // 4096 uniformly-kept units: raw bitmap needs 512 bytes of mask;
    // the RLE path must beat that by an order of magnitude.
    let sm = SubModel::from_keep(vec![vec![true; 4096]]);
    let mut buf = Vec::new();
    frame::encode_round_offer(&mut buf, 0, 0, 0, 0.1, f64::NAN, &sm);
    assert!(
        buf.len() < 4096 / 8,
        "uniform 4096-unit mask should RLE-compress, frame is {} bytes",
        buf.len()
    );
    let (view, _) = frame::parse_frame(&buf).unwrap();
    assert_eq!(frame::parse_round_offer(&view).unwrap().submodel().keep, sm.keep);

    // Worst case for RLE (strict alternation) must still round-trip —
    // the encoder falls back to the bitmap tag rather than inflating.
    let alternating: Vec<bool> = (0..777).map(|i| i % 2 == 0).collect();
    let sm = SubModel::from_keep(vec![alternating]);
    let mut buf = Vec::new();
    frame::encode_round_offer(&mut buf, 0, 0, 0, 0.1, f64::NAN, &sm);
    let (view, _) = frame::parse_frame(&buf).unwrap();
    assert_eq!(frame::parse_round_offer(&view).unwrap().submodel().keep, sm.keep);
}

/// StateSync frames carry a client's full resume state — RNG raw
/// state, participation count, DGC residuals — bit-exactly.
#[test]
fn state_sync_roundtrips_fields_and_residuals() {
    let mut rng = Pcg64::new(7);
    for len in [0usize, 1, 33, 512] {
        let u: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let v: Vec<f32> = (0..len).map(|_| rng.normal_f32(-1.0, 0.5)).collect();
        let (state, inc) = (rng.next_u64() as u128 | (1 << 100), rng.next_u64() as u128 | 1);
        let mut buf = Vec::new();
        frame::encode_state_sync(&mut buf, 42, 9000, state, inc, &u, &v);
        let (view, _) = frame::parse_frame(&buf).unwrap();
        let sync = frame::parse_state_sync(&view).unwrap();
        assert_eq!(sync.client, 42);
        assert_eq!(sync.participations, 9000);
        assert_eq!(sync.rng_state, state);
        assert_eq!(sync.rng_inc, inc);
        assert_eq!(sync.residual_len(), len);
        let (mut ru, mut rv) = (Vec::new(), Vec::new());
        sync.read_residuals(&mut ru, &mut rv);
        assert_eq!(ru, u, "len {len}");
        assert_eq!(rv, v, "len {len}");
    }
}

#[test]
fn update_up_roundtrips_fields() {
    let mut buf = Vec::new();
    let body = [1u8, 2, 3, 4, 5];
    let base = frame::begin_update_up(&mut buf, 11, 4, 123, -0.75, frame::UPDATE_RAW);
    buf.extend_from_slice(&body);
    frame::end_frame(&mut buf, base);
    let (view, _) = frame::parse_frame(&buf).unwrap();
    let upd = frame::parse_update_up(&view).unwrap();
    assert_eq!(
        (upd.round, upd.client, upd.samples, upd.update_kind),
        (11, 4, 123, frame::UPDATE_RAW)
    );
    assert_eq!(upd.loss, -0.75);
    assert_eq!(upd.payload, body);
}

/// Truncation at EVERY prefix length must be a `FrameError` (almost
/// always `Truncated`; a cut inside the header can also surface as a
/// magic/version error on garbage) — never a panic.
#[test]
fn truncation_at_every_byte_is_an_error() {
    for f in frame_corpus(3) {
        for cut in 0..f.len() {
            let r = frame::parse_frame(&f[..cut]);
            assert!(r.is_err(), "prefix of {cut}/{} bytes parsed", f.len());
        }
    }
}

/// CRC-32 detects every single-bit error, and the length/magic/version
/// checks cover the prefix fields — so flipping ANY single bit of a
/// valid frame must yield an error, never a panic and never a clean
/// parse.
#[test]
fn any_single_bit_flip_is_detected() {
    for f in frame_corpus(4) {
        for byte in 0..f.len() {
            for bit in 0..8u8 {
                let mut corrupt = f.clone();
                corrupt[byte] ^= 1 << bit;
                let r = frame::parse_frame(&corrupt);
                assert!(
                    r.is_err(),
                    "flip byte {byte} bit {bit} of a {}-byte frame parsed cleanly",
                    f.len()
                );
            }
        }
    }
}

/// Random garbage (arbitrary bytes, arbitrary lengths) never panics
/// the parser.
#[test]
fn random_garbage_never_panics() {
    let gen = UsizeIn(0, 4096);
    afd::prop::check("garbage frames", &gen, 60, |&n| {
        let mut rng = Pcg64::new(n as u64 + 99);
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        // Any Result is fine — the property is "no panic, no hang".
        let _ = frame::parse_frame(&bytes);
        // Also exercise the typed parsers on whatever view survives.
        if let Ok((view, _)) = frame::parse_frame(&bytes) {
            let _ = frame::parse_round_offer(&view);
            let _ = frame::parse_update_up(&view);
            let _ = frame::parse_model_down(&view);
            let _ = frame::parse_round_close(&view);
            let _ = frame::parse_config(&view);
            let _ = frame::parse_ready(&view);
            let _ = frame::parse_hello(&view);
            let _ = frame::parse_state_sync(&view);
            let _ = frame::parse_telemetry(&view);
        }
        Ok(())
    });
}

/// Telemetry frames from the corpus round-trip through the owned
/// parser: every section count, span field, and delta survives.
#[test]
fn telemetry_frames_roundtrip_through_the_parser() {
    let mut parsed = 0;
    for f in frame_corpus(11) {
        let (view, _) = frame::parse_frame(&f).unwrap();
        if view.kind != FrameKind::Telemetry {
            continue;
        }
        let msg = frame::parse_telemetry(&view).expect("corpus telemetry parses");
        parsed += 1;
        for t in &msg.threads {
            assert!(!t.name.is_empty());
            for s in &t.spans {
                assert!((s.stage as usize) < frame::TELEMETRY_STAGE_LIMIT as usize);
            }
        }
    }
    assert!(parsed >= 4, "corpus should carry telemetry frames, got {parsed}");
}

/// Hostile section counts inside a CRC-valid envelope must be rejected
/// by the cap checks with a typed error — never an allocation of
/// count × size or a panic.
#[test]
fn telemetry_hostile_counts_error_without_allocating() {
    let mut buf = Vec::new();
    {
        let mut enc = frame::TelemetryEncoder::begin(&mut buf, 1, 2);
        enc.begin_threads();
        enc.begin_thread(0, "main", 0);
        enc.span(1, 0, 10, 5, 0, 0);
        enc.end_threads();
        enc.begin_counters();
        enc.counter(0, 1);
        enc.end_counters();
        enc.begin_gauges();
        enc.end_gauges();
        enc.begin_hists();
        enc.end_hists();
        enc.finish();
    }
    // Payload layout: round u32 ‖ now u64 ‖ thread count u32 ‖ ...
    let thread_count_at = frame::HEADER_LEN + 4 + 8;
    for hostile in [u32::MAX, (frame::MAX_TELEMETRY_THREADS as u32) + 1] {
        let mut v = buf.clone();
        v[thread_count_at..thread_count_at + 4].copy_from_slice(&hostile.to_le_bytes());
        let n = v.len();
        let crc = frame::crc32(&v[..n - frame::CRC_LEN]).to_le_bytes();
        v[n - 4..].copy_from_slice(&crc);
        let (view, _) = frame::parse_frame(&v).expect("envelope still valid");
        match frame::parse_telemetry(&view) {
            Err(FrameError::BadPayload { kind, .. }) => {
                assert_eq!(kind, FrameKind::Telemetry)
            }
            other => panic!("hostile thread count {hostile}: want BadPayload, got {other:?}"),
        }
    }
}

/// A peer still speaking wire v2 gets a diagnosable version refusal —
/// the error names both versions so the operator knows which binary
/// is stale.
#[test]
fn v2_peer_gets_a_diagnosable_version_refusal() {
    let mut buf = Vec::new();
    frame::encode_hello(&mut buf, 42);
    assert_eq!(buf[2], frame::WIRE_VERSION);
    buf[2] = 2;
    // Re-seal the CRC so only the version differs — the check order
    // must surface BadVersion, not BadCrc.
    let n = buf.len();
    let crc = frame::crc32(&buf[..n - frame::CRC_LEN]).to_le_bytes();
    buf[n - 4..].copy_from_slice(&crc);
    match frame::parse_frame(&buf) {
        Err(FrameError::BadVersion { got, want }) => {
            assert_eq!((got, want), (2, frame::WIRE_VERSION));
        }
        other => panic!("want BadVersion, got {other:?}"),
    }
}

/// Payload-level malformation (valid frame envelope, short payload)
/// errors with the field name, never panics.
#[test]
fn short_payloads_error_diagnosably() {
    // An Ack frame whose payload is 3 bytes instead of 8.
    let mut buf = Vec::new();
    let base = frame::begin_frame(&mut buf, FrameKind::Ack);
    buf.extend_from_slice(&[1, 2, 3]);
    frame::end_frame(&mut buf, base);
    let (view, _) = frame::parse_frame(&buf).unwrap();
    match frame::parse_round_close(&view) {
        Err(FrameError::BadPayload { kind, .. }) => assert_eq!(kind, FrameKind::Ack),
        other => panic!("want BadPayload, got {other:?}"),
    }

    // A RoundOffer whose group region is cut mid-bitmap.
    let sm = SubModel::from_keep(vec![vec![true; 20]]);
    let mut full = Vec::new();
    frame::encode_round_offer(&mut full, 1, 2, 3, 0.1, f64::NAN, &sm);
    let (view, _) = frame::parse_frame(&full).unwrap();
    let payload = view.payload;
    let mut cut = Vec::new();
    let base = frame::begin_frame(&mut cut, FrameKind::RoundOffer);
    cut.extend_from_slice(&payload[..payload.len() - 1]);
    frame::end_frame(&mut cut, base);
    let (view, _) = frame::parse_frame(&cut).unwrap();
    assert!(matches!(
        frame::parse_round_offer(&view),
        Err(FrameError::BadPayload { .. })
    ));
}

#[test]
fn wrong_kind_routing_is_an_error() {
    let mut buf = Vec::new();
    frame::encode_hello(&mut buf, 1);
    let (view, _) = frame::parse_frame(&buf).unwrap();
    assert!(frame::parse_round_offer(&view).is_err());
    assert!(frame::parse_update_up(&view).is_err());
    assert!(frame::parse_model_down(&view).is_err());
    assert!(frame::parse_config(&view).is_err());
    assert!(frame::parse_state_sync(&view).is_err());
}
