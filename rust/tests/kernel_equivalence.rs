//! Equivalence suite for the PR's two hot-path rewrites:
//!
//! * the kernel-based `train_epoch` (blocked GEMM + fused epilogues +
//!   SGD rank updates) against the retained scalar reference —
//!   **bit-for-bit** at batch-block size 1 (identical accumulation
//!   order), within 1e-5 relative error for blocked configs;
//! * `PackPlan`-based pack/unpack/mask against the legacy
//!   `pack_values`/`unpack_values`/`coordinate_mask` — exact identity
//!   on random sub-models, including repeat/fixed axis packing.

use afd::model::manifest::{AxisPack, DType, MaskGroup, ParamSeg, VariantSpec};
use afd::model::packing::{self, PackPlan};
use afd::model::submodel::SubModel;
use afd::runtime::native::{mlp_spec, NativeMlp};
use afd::runtime::{BatchInput, EpochData, ModelRuntime};
use afd::tensor::kernels::Workspace;
use afd::util::rng::Pcg64;

fn random_epoch(spec: &VariantSpec, seed: u64) -> EpochData {
    let mut rng = Pcg64::new(seed);
    let d = spec.input_shape[0];
    let n = spec.num_batches * spec.batch_size;
    let mut xs = vec![0.0f32; n * d];
    for v in xs.iter_mut() {
        // Mix of zeros (sparse fast path) and dense values.
        if rng.next_f64() < 0.3 {
            *v = 0.0;
        } else {
            *v = rng.normal_f32(0.0, 1.0);
        }
    }
    let ys: Vec<i32> = (0..n)
        .map(|_| rng.below(spec.classes as u64) as i32)
        .collect();
    EpochData {
        xs: BatchInput::F32(xs),
        ys,
    }
}

fn partial_mask(h: usize, drop_every: usize) -> Vec<Vec<f32>> {
    let mask: Vec<f32> = (0..h)
        .map(|j| if j % drop_every == 0 { 0.0 } else { 1.0 })
        .collect();
    vec![mask]
}

/// Block size 1: the kernel path must reproduce the scalar reference
/// bit-for-bit — same accumulation order, same zero-skips, same update
/// sequence — across masks and epochs.
#[test]
fn block_one_is_bit_identical_to_scalar_reference() {
    // Odd sizes exercise partial tail blocks everywhere.
    let spec = mlp_spec("eq", 33, 17, 7, 5, 3, 0.15);
    let mlp = NativeMlp::new(spec.clone());
    let masks = partial_mask(17, 4);
    let mut p_ref = mlp.init_params(42);
    let mut p_ker = p_ref.clone();
    let mut ws = Workspace::new();
    for epoch in 0..3 {
        let data = random_epoch(&spec, 100 + epoch);
        let out = mlp
            .train_epoch_scalar(&p_ref, &masks, &data, 0.15)
            .unwrap();
        let loss_ker = mlp
            .train_epoch_with_block(&mut ws, &mut p_ker, &masks, &data, 0.15, 1)
            .unwrap();
        assert_eq!(
            out.mean_loss.to_bits(),
            loss_ker.to_bits(),
            "epoch {epoch} loss"
        );
        p_ref = out.params;
        for (i, (a, b)) in p_ref.iter().zip(&p_ker).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "epoch {epoch} param {i}: {a} vs {b}"
            );
        }
    }
}

/// Blocked configs (including the default block) stay within 1e-5
/// relative L2 of the scalar reference over multiple epochs.
#[test]
fn blocked_configs_match_scalar_reference_within_tolerance() {
    let spec = mlp_spec("eq", 20, 24, 5, 12, 4, 0.1);
    let mlp = NativeMlp::new(spec.clone());
    let masks = partial_mask(24, 5);
    let init = mlp.init_params(7);
    for bb in [2usize, 4, 8, 16] {
        let mut p_ref = init.clone();
        let mut p_ker = init.clone();
        let mut ws = Workspace::new();
        for epoch in 0..3 {
            let data = random_epoch(&spec, 500 + epoch);
            let out = mlp.train_epoch_scalar(&p_ref, &masks, &data, 0.1).unwrap();
            let loss_ker = mlp
                .train_epoch_with_block(&mut ws, &mut p_ker, &masks, &data, 0.1, bb)
                .unwrap();
            p_ref = out.params;
            assert!(
                (out.mean_loss - loss_ker).abs() <= 1e-5 * out.mean_loss.abs().max(1.0),
                "bb={bb} epoch {epoch}: loss {} vs {loss_ker}",
                out.mean_loss
            );
        }
        let err = afd::tensor::rel_l2_error(&p_ker, &p_ref);
        assert!(err <= 1e-5, "bb={bb}: rel err {err}");
    }
}

/// The trait entry points ride the kernel path: `train_epoch` (the
/// allocating API) and `train_epoch_in` (the workspace API) must agree
/// exactly with `train_epoch_with_block` at the default block.
#[test]
fn trait_entry_points_agree_with_explicit_block() {
    let spec = mlp_spec("eq", 12, 10, 4, 6, 2, 0.2);
    let mlp = NativeMlp::new(spec.clone());
    let masks = partial_mask(10, 3);
    let init = mlp.init_params(3);
    let data = random_epoch(&spec, 9);

    let out = mlp.train_epoch(&init, &masks, &data, 0.2).unwrap();

    let mut ws = Workspace::new();
    let mut p_in = init.clone();
    let loss_in = mlp
        .train_epoch_in(&mut ws, &mut p_in, &masks, &data, 0.2)
        .unwrap();

    assert_eq!(out.mean_loss.to_bits(), loss_in.to_bits());
    for (a, b) in out.params.iter().zip(&p_in) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// Dropped units must stay bit-untouched through the kernel path at
/// every block size (the masking contract the whole coordinator relies
/// on).
#[test]
fn blocked_updates_keep_dropped_units_untouched() {
    let spec = mlp_spec("eq", 9, 11, 3, 7, 2, 0.1);
    let mlp = NativeMlp::new(spec.clone());
    let (d, h, c) = (9usize, 11usize, 3usize);
    let dropped = [0usize, 5, 10];
    let mut mask = vec![1.0f32; h];
    for &j in &dropped {
        mask[j] = 0.0;
    }
    let init = mlp.init_params(5);
    let data = random_epoch(&spec, 77);
    for bb in [1usize, 4, 8] {
        let mut p = init.clone();
        let mut ws = Workspace::new();
        mlp.train_epoch_with_block(&mut ws, &mut p, &[mask.clone()], &data, 0.1, bb)
            .unwrap();
        for &j in &dropped {
            for i in 0..d {
                assert_eq!(p[i * h + j], init[i * h + j], "bb={bb} w1[{i},{j}]");
            }
            assert_eq!(p[d * h + j], init[d * h + j], "bb={bb} b1[{j}]");
            for k in 0..c {
                let off = d * h + h + j * c + k;
                assert_eq!(p[off], init[off], "bb={bb} w2[{j},{k}]");
            }
        }
    }
}

// ---------------------------------------------------------------------
// PackPlan vs legacy packing
// ---------------------------------------------------------------------

/// A spec with repeat/fixed axis packing (LSTM-style recurrent rows) —
/// the tiling cases `mlp_spec` never exercises.
fn lstmish_spec() -> VariantSpec {
    let packed_rows = AxisPack {
        group: "u".to_string(),
        count: 6,
        repeat: 4,
        fixed: 2,
    };
    let packed_cols = AxisPack {
        group: "u".to_string(),
        count: 6,
        repeat: 1,
        fixed: 0,
    };
    let params = vec![
        ParamSeg {
            name: "wr".into(),
            shape: vec![26, 3],
            size: 78,
            offset: 0,
            trainable: true,
            transmit: true,
            rows: Some(packed_rows),
            cols: None,
            flops_per_sample: 10.0,
        },
        ParamSeg {
            name: "b".into(),
            shape: vec![6],
            size: 6,
            offset: 78,
            trainable: true,
            transmit: true,
            rows: None,
            cols: Some(packed_cols),
            flops_per_sample: 0.0,
        },
        ParamSeg {
            name: "frozen".into(),
            shape: vec![4],
            size: 4,
            offset: 84,
            trainable: false,
            transmit: false,
            rows: None,
            cols: None,
            flops_per_sample: 0.0,
        },
    ];
    VariantSpec {
        name: "lstmish".to_string(),
        kind: "lstm".to_string(),
        dataset: "synthetic".to_string(),
        lr: 0.1,
        batch_size: 1,
        num_batches: 1,
        classes: 2,
        vocab: 0,
        input_shape: vec![1],
        input_dtype: DType::F32,
        num_params: 88,
        params,
        mask_groups: vec![MaskGroup {
            name: "u".to_string(),
            size: 6,
            kind: "lstm_units".to_string(),
        }],
        train_hlo: String::new(),
        eval_hlo: String::new(),
        init_params: String::new(),
        train_args: vec![],
        train_outputs: vec![],
        eval_args: vec![],
        eval_outputs: vec![],
    }
}

fn assert_plan_matches_legacy(spec: &VariantSpec, sm: &SubModel, full: &[f32]) {
    let plan = PackPlan::build(spec, sm);
    assert_eq!(plan.packed_len(), packing::packed_model_elems(spec, sm));
    assert_eq!(plan.wire_bytes(), packing::submodel_wire_bytes(spec, sm));

    let legacy_packed = packing::pack_values(spec, full, sm);
    let mut plan_packed = Vec::new();
    plan.pack_into(full, &mut plan_packed);
    assert_eq!(plan_packed, legacy_packed);

    let mut legacy_full = vec![-7.0f32; spec.num_params];
    let mut plan_full = vec![-7.0f32; spec.num_params];
    packing::unpack_values(spec, &legacy_packed, sm, &mut legacy_full);
    plan.unpack_from(&plan_packed, &mut plan_full);
    assert_eq!(plan_full, legacy_full);

    let mut cm = vec![false; spec.num_params];
    plan.mark_coord_mask(&mut cm);
    assert_eq!(cm, packing::coordinate_mask(spec, sm));
}

#[test]
fn pack_plan_matches_legacy_on_random_submodels() {
    let mut rng = Pcg64::new(2024);
    let mlp = mlp_spec("pp", 14, 12, 5, 4, 2, 0.1);
    let lstm = lstmish_spec();
    for spec in [&mlp, &lstm] {
        let full: Vec<f32> = (0..spec.num_params).map(|i| i as f32).collect();
        let g = spec.mask_groups[0].size;
        for _ in 0..20 {
            let k = 1 + rng.below(g as u64) as usize;
            let kept = vec![rng.sample_indices(g, k)];
            let sm = SubModel::from_kept_indices(spec, &kept);
            assert_plan_matches_legacy(spec, &sm, &full);
        }
        assert_plan_matches_legacy(spec, &SubModel::full(spec), &full);
    }
}
