//! Hierarchical aggregation conformance: a tree of edge aggregators
//! must be a pure topology knob — bit-identical to the flat
//! [`ShardedFedAvg`] and to the single-threaded [`FedAvg`] reference at
//! every tree shape, through direct batched rounds and through whole
//! experiments under all three scheduler policies.

use std::sync::Arc;

use afd::aggregation::{AddOp, FedAvg, HierarchicalFedAvg, ShardedFedAvg};
use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::{run_experiment, Experiment};
use afd::metrics::RoundRecord;
use afd::model::packing::PackPlan;
use afd::model::submodel::SubModel;
use afd::runtime::native::mlp_spec;
use afd::util::pool::LazyPool;
use afd::util::rng::Pcg64;

fn assert_bit_identical(a: &RoundRecord, b: &RoundRecord, what: &str) {
    assert_eq!(a.round, b.round, "{what}");
    assert_eq!(a.round_s.to_bits(), b.round_s.to_bits(), "{what} round {}", a.round);
    assert_eq!(
        a.train_loss.to_bits(),
        b.train_loss.to_bits(),
        "{what} round {}",
        a.round
    );
    assert_eq!(
        a.eval_acc.map(f64::to_bits),
        b.eval_acc.map(f64::to_bits),
        "{what} round {}",
        a.round
    );
    assert_eq!(a.down_bytes, b.down_bytes, "{what} round {}", a.round);
    assert_eq!(a.up_bytes, b.up_bytes, "{what} round {}", a.round);
    assert_eq!(a.arrived, b.arrived, "{what} round {}", a.round);
    assert_eq!(a.cut, b.cut, "{what}");
    assert_eq!(a.dropped, b.dropped, "{what}");
}

/// Direct three-way check: a mixed batch of masked/planned/full ops
/// through [`FedAvg`] (reference), [`ShardedFedAvg`] (flat) and
/// [`HierarchicalFedAvg`] at several tree shapes yields bitwise the
/// same output vector.
#[test]
fn tree_matches_flat_and_reference_on_mixed_batches() {
    let spec = mlp_spec("h", 24, 16, 6, 8, 3, 0.1);
    let n = spec.num_params;
    let mut rng = Pcg64::new(5);
    let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    // Three clients: one masked, one planned, one full.
    let vals: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect())
        .collect();
    let mask: Vec<bool> = (0..n).map(|i| i % 3 != 0).collect();
    let sm = SubModel::from_kept_indices(&spec, &[vec![0, 2, 5, 7, 9, 12, 14]]);
    let plan = PackPlan::build(&spec, &sm);

    // Reference result through the serial FedAvg.
    let mut reference = FedAvg::new(n);
    reference.add_masked(&vals[0], &mask, 10.0);
    let mut cmask = vec![false; n];
    plan.mark_coord_mask(&mut cmask);
    reference.add_masked(&vals[1], &cmask, 25.0);
    reference.add_full(&vals[2], 5.0);
    let want = reference.finalize(&base);

    let ops = [
        AddOp::Masked {
            values: &vals[0],
            coord_mask: &mask,
            n_c: 10.0,
        },
        AddOp::Planned {
            values: &vals[1],
            plan: &plan,
            n_c: 25.0,
        },
        AddOp::Full {
            values: &vals[2],
            n_c: 5.0,
        },
    ];

    let pool = Arc::new(LazyPool::new(4));
    for shards in [1usize, 3, 8] {
        let mut flat = ShardedFedAvg::new(n, shards, Arc::clone(&pool));
        let mut out = Vec::new();
        flat.aggregate_batch(&ops, &base, &mut out);
        assert_eq!(out.len(), want.len());
        for (x, y) in out.iter().zip(&want) {
            assert_eq!(x.to_bits(), y.to_bits(), "flat shards={shards}");
        }
    }
    for (levels, fanout) in [(2usize, 2usize), (2, 8), (3, 2), (3, 4), (5, 3)] {
        let mut tree = HierarchicalFedAvg::new(n, levels, fanout, Arc::clone(&pool));
        let mut out = Vec::new();
        tree.aggregate_batch(&ops, &base, &mut out);
        assert_eq!(out.len(), want.len());
        for (x, y) in out.iter().zip(&want) {
            assert_eq!(x.to_bits(), y.to_bits(), "tree {levels}x{fanout}");
        }
    }
}

/// Whole-experiment invariance: for every scheduler policy, a run with
/// tree aggregation (several shapes) is record-for-record bit-identical
/// to the same run with flat sharded aggregation.
#[test]
fn every_policy_is_tree_shape_invariant() {
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
        cfg.rounds = 5;
        cfg.eval_every = 2;
        cfg.sched.policy = policy.into();
        cfg.sched.buffer_k = 2;
        let flat = run_experiment(&cfg).unwrap();
        for (levels, fanout) in [(2usize, 4usize), (3, 2)] {
            let mut tree_cfg = cfg.clone();
            tree_cfg.sharding.tree_levels = levels;
            tree_cfg.sharding.tree_fanout = fanout;
            let tree = run_experiment(&tree_cfg).unwrap();
            assert_eq!(flat.records.len(), tree.records.len());
            for (x, y) in flat.records.iter().zip(&tree.records) {
                assert_bit_identical(x, y, &format!("{policy} {levels}x{fanout}"));
            }
        }
    }
}

/// The tree path against the retained serial [`FedAvg`] loop: the sync
/// engine with hierarchical aggregation must still reproduce
/// `step_serial_reference` byte-for-byte, global model included.
#[test]
fn tree_sync_engine_matches_fedavg_serial_reference() {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 6;
    cfg.eval_every = 3;
    cfg.uplink_dgc = true;
    cfg.sharding.tree_levels = 3;
    cfg.sharding.tree_fanout = 3;
    assert_eq!(cfg.sched.policy, "sync");

    let mut engine = Experiment::build(&cfg).unwrap();
    let mut serial = Experiment::build(&cfg).unwrap();
    for round in 1..=cfg.rounds {
        let a = engine.step(round).unwrap();
        let b = serial.step_serial_reference(round).unwrap();
        assert_bit_identical(&a, &b, "tree-vs-serial");
    }
    for (x, y) in engine.global.iter().zip(&serial.global) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
