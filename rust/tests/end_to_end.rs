//! Artifact-free end-to-end system tests on the native backend:
//! the paper's qualitative claims must hold on the full coordinator
//! stack (selection → downlink codec → local training → uplink DGC →
//! FedAvg → network accounting).

use afd::config::{Backend, ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;

fn native_base(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.backend = Backend::Native;
    cfg.native_dims = (48, 64, 6);
    cfg.num_clients = 24;
    cfg.client_fraction = 0.3;
    cfg.rounds = 40;
    cfg.eval_every = 4;
    cfg.seed = seed;
    cfg.data.samples_per_client = (40, 100);
    cfg
}

#[test]
fn full_stack_learns_under_every_method() {
    for (dropout, downlink, dgc) in [
        ("none", "raw", false),
        ("none", "quant8", true),
        ("fd", "quant8", true),
        ("afd_multi", "quant8", true),
        ("afd_single", "quant8", true),
    ] {
        let mut cfg = native_base(3);
        cfg.dropout = dropout.into();
        cfg.downlink = downlink.into();
        cfg.uplink_dgc = dgc;
        let r = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("{dropout}/{downlink}: {e}"));
        let best = r.best_accuracy();
        assert!(
            best > 0.55,
            "{dropout}+{downlink}+dgc={dgc} should learn, best={best}"
        );
    }
}

#[test]
fn compression_shrinks_time_but_keeps_accuracy() {
    // The paper's core claim shape: AFD+DGC reaches comparable (or
    // better) accuracy in far less simulated time than No Compression.
    let mut none = native_base(1);
    none.dropout = "none".into();
    none.downlink = "raw".into();
    none.uplink_dgc = false;
    // Payload-dominated regime.
    none.native_dims = (128, 192, 8);

    let mut afd = none.clone();
    afd.dropout = "afd_multi".into();
    afd.downlink = "quant8".into();
    afd.uplink_dgc = true;

    let r_none = run_experiment(&none).unwrap();
    let r_afd = run_experiment(&afd).unwrap();

    assert!(
        r_afd.total_sim_seconds() < r_none.total_sim_seconds() / 4.0,
        "AFD+DGC should be ≥4× faster in simulated time: {} vs {}",
        r_afd.total_sim_seconds(),
        r_none.total_sim_seconds()
    );
    assert!(
        r_afd.best_accuracy() > r_none.best_accuracy() - 0.1,
        "accuracy must not collapse: afd {} vs none {}",
        r_afd.best_accuracy(),
        r_none.best_accuracy()
    );
}

#[test]
fn afd_multi_updates_score_maps_through_training() {
    // Run the real loop, then verify AFD state changed (the strategy is
    // driven through the full coordinator, not in isolation).
    use afd::dropout::{MultiModelAfd, SubmodelStrategy};
    use afd::util::rng::Pcg64;

    // Direct strategy exercise with realistic loss sequences from an
    // actual native run.
    let cfg = native_base(7);
    let report = run_experiment(&cfg).unwrap();
    let losses: Vec<f64> = report.records.iter().map(|r| r.train_loss).collect();
    assert!(losses.len() >= 10);

    let spec = afd::runtime::native::mlp_spec("t", 48, 64, 6, 10, 5, 0.1);
    let mut strat = MultiModelAfd::new(&spec, 1, 0.25);
    let mut rng = Pcg64::new(0);
    for (i, &l) in losses.iter().enumerate() {
        let _ = strat.select(i + 1, 0, &mut rng);
        strat.report_loss(i + 1, 0, l);
    }
    // Real training losses decrease overall → the map must accumulate.
    assert!(
        strat.score_map(0).total() > 0.0,
        "decreasing real losses must credit the score map"
    );
}

#[test]
fn dgc_residuals_eventually_ship() {
    // With DGC, early-round residuals must surface later: total uplink
    // bytes stay bounded but coverage (aggregated coordinates) over many
    // rounds must exceed one round's sparse fraction.
    let mut cfg = native_base(9);
    cfg.dropout = "none".into();
    cfg.downlink = "raw".into();
    cfg.uplink_dgc = true;
    cfg.dgc.sparsity = 0.02;
    cfg.rounds = 20;
    let r = run_experiment(&cfg).unwrap();
    // The run must still learn despite 98% sparsification.
    assert!(r.best_accuracy() > 0.5, "acc {}", r.best_accuracy());
    // And uplink ≪ downlink (dense raw down vs sparse up).
    assert!(r.total_up_bytes() * 4 < r.total_down_bytes());
}

#[test]
fn fdr_sweep_trades_bytes_for_capacity() {
    // Higher FDR ⇒ smaller sub-models ⇒ fewer downlink bytes.
    let mut bytes = Vec::new();
    for fdr in [0.1, 0.25, 0.5] {
        let mut cfg = native_base(5);
        cfg.dropout = "fd".into();
        cfg.fdr = fdr;
        cfg.rounds = 6;
        let r = run_experiment(&cfg).unwrap();
        bytes.push(r.total_down_bytes());
    }
    assert!(
        bytes[0] > bytes[1] && bytes[1] > bytes[2],
        "down bytes must fall with FDR: {bytes:?}"
    );
}

#[test]
fn single_model_afd_shares_submodel_in_cohort() {
    // keep_fraction identical across rounds implies consistent FDR; the
    // strategy itself is validated in unit tests — here we make sure the
    // coordinator path keeps cohort-wide selection consistent (one
    // sub-model per round ⇒ per-round keep_fraction exactly the group
    // quantile of the FDR).
    let mut cfg = native_base(11);
    cfg.dropout = "afd_single".into();
    cfg.fdr = 0.25;
    cfg.rounds = 8;
    let r = run_experiment(&cfg).unwrap();
    for rec in &r.records {
        assert!((rec.keep_fraction - 0.75).abs() < 0.02, "{}", rec.keep_fraction);
    }
}
