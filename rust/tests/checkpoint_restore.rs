//! Coordinator checkpoint/restore: a run interrupted at a round
//! boundary and resumed in a fresh process-equivalent (new `Experiment`
//! from the same config) must be bit-identical to the uninterrupted
//! run — JSONL records and final model hash — and every way a
//! checkpoint can be unusable (corruption, truncation, config drift,
//! continuous policy) must be a typed error, never a wrong result.

use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::Experiment;
use afd::metrics::RoundRecord;
use afd::util::model_hash;

fn smoke_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
    cfg.rounds = 6;
    cfg.eval_every = 2;
    cfg
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("afd_{}_{name}.ckpt", std::process::id()))
}

fn jsonl(recs: &[RoundRecord]) -> Vec<String> {
    recs.iter().map(|r| r.to_json().to_string_compact()).collect()
}

fn run_uninterrupted(cfg: &ExperimentConfig) -> (Vec<String>, u64) {
    let mut exp = Experiment::build(cfg).unwrap();
    for round in 1..=cfg.rounds {
        exp.step(round).unwrap();
    }
    (jsonl(exp.records()), model_hash(&exp.global))
}

/// The acceptance bar: save at round 3, throw the experiment away,
/// rebuild from config, restore, continue — records and model hash
/// must match the uninterrupted run bit-for-bit.
#[test]
fn restore_continues_bit_identically() {
    for policy in ["sync", "overselect"] {
        let mut cfg = smoke_cfg();
        cfg.sched.policy = policy.into();
        let (full_recs, full_hash) = run_uninterrupted(&cfg);

        let path = tmp_path(&format!("resume_{policy}"));
        {
            let mut exp = Experiment::build(&cfg).unwrap();
            for round in 1..=3 {
                exp.step(round).unwrap();
            }
            exp.save_checkpoint(&path, 3).unwrap();
            // The "crash": drop the whole experiment on the floor.
        }
        let mut exp = Experiment::build(&cfg).unwrap();
        let completed = exp.restore_from_checkpoint(&path).unwrap();
        assert_eq!(completed, 3, "{policy}");
        for round in (completed as usize + 1)..=cfg.rounds {
            exp.step(round).unwrap();
        }
        assert_eq!(jsonl(exp.records()), full_recs, "{policy}");
        assert_eq!(model_hash(&exp.global), full_hash, "{policy}");
        let _ = std::fs::remove_file(&path);
    }
}

/// Checkpoints survive their own serialization: saving again right
/// after a restore reproduces the same file byte-for-byte (nothing is
/// lost or reordered by a round-trip through disk).
#[test]
fn save_restore_save_is_byte_stable() {
    let cfg = smoke_cfg();
    let p1 = tmp_path("stable1");
    let p2 = tmp_path("stable2");
    {
        let mut exp = Experiment::build(&cfg).unwrap();
        for round in 1..=2 {
            exp.step(round).unwrap();
        }
        exp.save_checkpoint(&p1, 2).unwrap();
    }
    let mut exp = Experiment::build(&cfg).unwrap();
    exp.restore_from_checkpoint(&p1).unwrap();
    exp.save_checkpoint(&p2, 2).unwrap();
    let a = std::fs::read(&p1).unwrap();
    let b = std::fs::read(&p2).unwrap();
    assert_eq!(a, b, "restore must reconstruct the exact checkpointed state");
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);
}

/// Corruption anywhere in the file is a typed error on read — the CRC
/// trailer rejects it before any field is trusted.
#[test]
fn corrupt_or_truncated_checkpoint_is_a_typed_error() {
    let cfg = smoke_cfg();
    let path = tmp_path("corrupt");
    {
        let mut exp = Experiment::build(&cfg).unwrap();
        exp.step(1).unwrap();
        exp.save_checkpoint(&path, 1).unwrap();
    }
    let clean = std::fs::read(&path).unwrap();

    let mut flipped = clean.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x01;
    std::fs::write(&path, &flipped).unwrap();
    let mut exp = Experiment::build(&cfg).unwrap();
    let err = exp.restore_from_checkpoint(&path).unwrap_err();
    assert!(
        format!("{err:#}").contains("checkpoint"),
        "corruption error should name the checkpoint: {err:#}"
    );

    std::fs::write(&path, &clean[..clean.len() - 7]).unwrap();
    assert!(exp.restore_from_checkpoint(&path).is_err(), "truncated file must fail");

    // The experiment is still usable after failed restores.
    std::fs::write(&path, &clean).unwrap();
    assert_eq!(exp.restore_from_checkpoint(&path).unwrap(), 1);
    exp.step(2).unwrap();
    let _ = std::fs::remove_file(&path);
}

/// A checkpoint from a different config must be refused up front: the
/// fingerprint check catches drift before any state is loaded.
#[test]
fn config_drift_is_refused() {
    let cfg = smoke_cfg();
    let path = tmp_path("drift");
    {
        let mut exp = Experiment::build(&cfg).unwrap();
        exp.step(1).unwrap();
        exp.save_checkpoint(&path, 1).unwrap();
    }
    let mut other = cfg.clone();
    other.seed += 1;
    let mut exp = Experiment::build(&other).unwrap();
    let err = exp.restore_from_checkpoint(&path).unwrap_err();
    assert!(
        format!("{err:#}").contains("fingerprint"),
        "drift error should mention the fingerprint: {err:#}"
    );
    let _ = std::fs::remove_file(&path);
}

/// Continuous policies carry in-flight work across round boundaries;
/// checkpointing them would need heap serialization the format does
/// not promise — refusing is the contract.
#[test]
fn continuous_policy_refuses_to_checkpoint() {
    let mut cfg = smoke_cfg();
    cfg.sched.policy = "async_buffered".into();
    let path = tmp_path("async");
    let mut exp = Experiment::build(&cfg).unwrap();
    exp.step(1).unwrap();
    let err = exp.save_checkpoint(&path, 1).unwrap_err();
    assert!(
        format!("{err:#}").contains("continuous"),
        "refusal should explain itself: {err:#}"
    );
    assert!(!path.exists(), "a refused checkpoint must not leave a file");
    let _ = std::fs::remove_file(&path);
}
