//! SIMD-vs-scalar conformance: the dispatched entry points in
//! `afd::tensor::simd` must be **bit-identical** to the retained
//! scalar references (`simd::scalar`) on every input shape — including
//! non-multiple-of-lane-width tails, empty inputs, NaN/∞ and
//! tie-rounding cases — and the codec streams built on them must be
//! **byte-identical** between the two paths.
//!
//! Without `--features simd` (or on a non-AVX2 machine) the dispatch
//! resolves to scalar and these tests pass trivially; the CI `simd`
//! job runs the suite with the feature enabled, where every assertion
//! genuinely compares AVX2 output against the scalar reference.
//! `rust/tests/kernel_equivalence.rs` (also run under the feature)
//! supplies the end-to-end ≤1e-5 / bit-identity training contract on
//! top.

use afd::compression::quant::{sign_stream, HadamardQuant8, DEFAULT_BLOCK};
use afd::compression::{dgc, DenseCodec};
use afd::tensor::simd::{self, scalar};
use afd::util::rng::Pcg64;

fn gauss(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// Lengths that cover empty, sub-lane, exact-lane and ragged tails.
const LENS: [usize; 9] = [0, 1, 3, 7, 8, 9, 16, 100, 257];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn elementwise_ops_are_bit_identical() {
    for &n in &LENS {
        let w = gauss(n, 1);
        let s = gauss(n, 2);
        let base = gauss(n, 3);

        let mut a = base.clone();
        let mut b = base.clone();
        simd::axpy_row(&mut a, 0.73, &w);
        scalar::axpy_row(&mut b, 0.73, &w);
        assert_eq!(bits(&a), bits(&b), "axpy_row n={n}");

        let mut a = base.clone();
        let mut b = base.clone();
        simd::div_inplace(&mut a, 3.7);
        scalar::div_inplace(&mut b, 3.7);
        assert_eq!(bits(&a), bits(&b), "div_inplace n={n}");

        let mut a = base.clone();
        let mut b = base.clone();
        simd::scale_inplace(&mut a, -0.41);
        scalar::scale_inplace(&mut b, -0.41);
        assert_eq!(bits(&a), bits(&b), "scale_inplace n={n}");

        let mut a = base.clone();
        let mut b = base.clone();
        simd::mul_inplace(&mut a, &s);
        scalar::mul_inplace(&mut b, &s);
        assert_eq!(bits(&a), bits(&b), "mul_inplace n={n}");

        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        let mask: Vec<f32> = (0..n).map(|i| (i % 3 != 0) as u8 as f32).collect();
        let mut pre = gauss(n, 4);
        if n > 8 {
            pre[1] = 0.0;
            pre[5] = -0.0;
            pre[8] = f32::NAN;
        }
        simd::relu_mask_row(&pre, &mask, &mut a);
        scalar::relu_mask_row(&pre, &mask, &mut b);
        assert_eq!(bits(&a), bits(&b), "relu_mask_row n={n}");

        let signs: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        simd::scaled_signed_mul(&base, &signs, 0.125, &mut a);
        scalar::scaled_signed_mul(&base, &signs, 0.125, &mut b);
        assert_eq!(bits(&a), bits(&b), "scaled_signed_mul n={n}");
    }
}

#[test]
fn colsum_updates_are_bit_identical() {
    for &n in &LENS {
        for rows in [1usize, 2, 5, 16] {
            let g = gauss(rows * n, (n + rows) as u64);
            let av = gauss(rows, 7);
            let w0 = gauss(n, 8);

            let mut a = w0.clone();
            let mut b = w0.clone();
            simd::weighted_colsum_sub(&mut a, &g, &av, 0.05);
            scalar::weighted_colsum_sub(&mut b, &g, &av, 0.05);
            assert_eq!(bits(&a), bits(&b), "weighted_colsum_sub n={n} rows={rows}");

            let mut a = w0.clone();
            let mut b = w0.clone();
            simd::colsum_sub(&mut a, &g, 0.05);
            scalar::colsum_sub(&mut b, &g, 0.05);
            assert_eq!(bits(&a), bits(&b), "colsum_sub n={n} rows={rows}");
        }
    }
}

#[test]
fn fwht_is_bit_identical_across_power_of_two_lengths() {
    for p in 0..=11 {
        let n = 1usize << p;
        let v = gauss(n, p as u64);
        let mut a = v.clone();
        let mut b = v;
        simd::fwht(&mut a);
        scalar::fwht(&mut b);
        assert_eq!(bits(&a), bits(&b), "fwht n={n}");
    }
}

#[test]
fn absmax_is_bit_identical_including_nan_and_signed_zero() {
    for &n in &LENS {
        let mut v = gauss(n, n as u64 + 77);
        if n >= 9 {
            v[0] = f32::NAN;
            v[4] = -0.0;
            v[8] = f32::NEG_INFINITY;
        }
        let a = simd::absmax(&v);
        let b = scalar::absmax(&v);
        assert_eq!(a.to_bits(), b.to_bits(), "absmax n={n}");
    }
}

#[test]
fn quantize_dequantize_are_bit_identical_including_edge_values() {
    // All byte values decode identically.
    let q: Vec<u8> = (0u16..256).map(|b| b as u8).collect();
    let mut a = vec![0.0f32; 256];
    let mut b = vec![0.0f32; 256];
    simd::dequantize_block(&q, 0.37, &mut a);
    scalar::dequantize_block(&q, 0.37, &mut b);
    assert_eq!(bits(&a), bits(&b), "dequantize all bytes");

    for &n in &LENS {
        let mut v = gauss(n, n as u64 + 5);
        for x in v.iter_mut() {
            *x *= 40.0; // spread across the clamp range
        }
        if n >= 9 {
            v[0] = 2.5; // tie: rounds to even on both paths
            v[1] = -2.5;
            v[2] = f32::NAN;
            v[3] = f32::INFINITY;
            v[4] = f32::NEG_INFINITY;
            v[5] = 126.9;
            v[6] = -127.0;
            v[7] = -0.2;
        }
        let mut a = vec![0u8; n];
        let mut b = vec![0u8; n];
        simd::quantize_block(&v, 1.0, &mut a);
        scalar::quantize_block(&v, 1.0, &mut b);
        assert_eq!(a, b, "quantize n={n}");
    }
}

#[test]
fn dgc_scan_and_gather_are_bit_identical() {
    for &n in &LENS {
        let delta = gauss(n, n as u64 + 31);
        let u0 = gauss(n, 32);
        let v0 = gauss(n, 33);

        let (mut ua, mut va) = (u0.clone(), v0.clone());
        let (mut ub, mut vb) = (u0.clone(), v0.clone());
        simd::dgc_scan(&mut ua, &mut va, &delta, 0.9, 0.35);
        scalar::dgc_scan(&mut ub, &mut vb, &delta, 0.9, 0.35);
        assert_eq!(bits(&ua), bits(&ub), "dgc_scan u n={n}");
        assert_eq!(bits(&va), bits(&vb), "dgc_scan v n={n}");

        let src = gauss(n.max(1) * 3, 34);
        let idx: Vec<u32> = (0..n as u32).map(|i| (i * 2) % src.len() as u32).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        simd::gather_extend(&mut a, &src, &idx);
        scalar::gather_extend(&mut b, &src, &idx);
        assert_eq!(bits(&a), bits(&b), "gather n={n}");
    }
}

/// Scalar-primitive reference encoder: the exact pipeline of
/// `HadamardQuant8::encode_into`, built ONLY from `simd::scalar` ops.
/// Comparing the production encoder (which dispatches) against this
/// byte-for-byte proves the codec stream is identical between the
/// SIMD and scalar paths.
fn quant8_encode_scalar_reference(values: &[f32], seed: u64, b: usize) -> Vec<u8> {
    let n = values.len();
    let nblocks = n.div_ceil(b);
    let inv_sqrt = 1.0 / (b as f32).sqrt();
    let mut signs_rng = sign_stream(seed);
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&(n as u32).to_le_bytes());
    let mut buf = vec![0.0f32; b];
    let mut signs = vec![0.0f32; b];
    for blk in 0..nblocks {
        let start = blk * b;
        let take = (n - start).min(b);
        buf[..take].copy_from_slice(&values[start..start + take]);
        buf[take..].fill(0.0);
        signs_rng.rademacher_fill(&mut signs);
        scalar::mul_inplace(&mut buf, &signs);
        scalar::fwht(&mut buf);
        let m = scalar::absmax(&buf);
        let scale = m * inv_sqrt;
        bytes.extend_from_slice(&scale.to_le_bytes());
        let qs = if scale > 0.0 { 127.0 / m } else { 0.0 };
        let base = bytes.len();
        bytes.resize(base + b, 0);
        scalar::quantize_block(&buf, qs, &mut bytes[base..]);
    }
    bytes
}

fn quant8_decode_scalar_reference(bytes: &[u8], seed: u64, b: usize) -> Vec<f32> {
    let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let nblocks = n.div_ceil(b);
    let inv_sqrt = 1.0 / (b as f32).sqrt();
    let mut signs_rng = sign_stream(seed);
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0.0f32; b];
    let mut signs = vec![0.0f32; b];
    let mut off = 4;
    for blk in 0..nblocks {
        let scale = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        off += 4;
        scalar::dequantize_block(&bytes[off..off + b], scale, &mut buf);
        off += b;
        scalar::fwht(&mut buf);
        signs_rng.rademacher_fill(&mut signs);
        let start = blk * b;
        let take = (n - start).min(b);
        let base = out.len();
        out.resize(base + take, 0.0);
        scalar::scaled_signed_mul(&buf[..take], &signs[..take], inv_sqrt, &mut out[base..]);
    }
    out
}

#[test]
fn quant8_streams_are_byte_identical_between_simd_and_scalar_paths() {
    let codec = HadamardQuant8::default();
    let mut rng = Pcg64::new(99);
    // Random lengths (ragged tails), empty, all-masked (all-zero
    // payload — what a fully-dropped sub-model segment encodes), and a
    // non-finite payload.
    let mut cases: Vec<Vec<f32>> = vec![
        Vec::new(),
        vec![0.0f32; 300],
        gauss(1, 1),
        gauss(255, 2),
        gauss(256, 3),
        gauss(257, 4),
        gauss(4096, 5),
    ];
    for _ in 0..10 {
        let n = 1 + rng.below(3000) as usize;
        cases.push(gauss(n, n as u64));
    }
    let mut with_nan = gauss(600, 6);
    with_nan[17] = f32::NAN;
    with_nan[300] = f32::INFINITY;
    cases.push(with_nan);

    for (i, xs) in cases.iter().enumerate() {
        let enc = codec.encode(xs, 7 + i as u64);
        let want = quant8_encode_scalar_reference(xs, 7 + i as u64, DEFAULT_BLOCK);
        assert_eq!(enc.bytes, want, "case {i} (len {})", xs.len());
        let dec = codec.decode(&enc, 7 + i as u64);
        let dec_want = quant8_decode_scalar_reference(&enc.bytes, 7 + i as u64, DEFAULT_BLOCK);
        assert_eq!(bits(&dec), bits(&dec_want), "decode case {i}");
    }
}

#[test]
fn dgc_streams_are_deterministic_across_paths() {
    // DGC's SIMD surface is dgc_scan + gather_extend (bit-identical
    // above); top-k selection and the wire format are shared scalar
    // code. This test pins the end-to-end stream: compress from
    // identical states must produce identical bytes — under
    // `--features simd` one process-wide dispatch level applies, and
    // the op-level bit-identity proves the stream equals the scalar
    // build's (also checked cross-build by CI running both jobs).
    for n in [1usize, 7, 129, 1000] {
        let mut a = dgc::DgcState::new(dgc::DgcConfig::default());
        let mut b = a.clone();
        for r in 0..4 {
            let d = gauss(n, (n + r) as u64);
            let ma = a.compress(&d);
            let mb = b.compress(&d);
            assert_eq!(ma, mb, "n={n} round {r}");
            // The stream decodes to the coordinates it claims.
            assert_eq!(dgc::decode(&ma).len(), n);
        }
    }
}
