//! Table 1: accuracy + convergence time + speedup, **non-IID** LEAF
//! datasets, Multi-Model AFD. Regenerates all three dataset rows.
//!
//! Paper setup: FDR 25%, 30% clients/round, 8-bit+Hadamard downlink,
//! DGC uplink; 1000/80/400 rounds; targets 75/50/82%. Here: scaled
//! workloads (synthetic LEAF, small model variants), same geometry.
//! Success = orderings/shape, not absolute minutes (DESIGN.md §1).
//!
//! Scale up with: AFD_BENCH_ROUNDS=120 AFD_BENCH_SEEDS=3 cargo bench

use afd::bench::tables::{env_usize, report_against_paper, run_grid, PaperRow};
use afd::config::{ExperimentConfig, Preset};

fn paper_rows(dataset: &str) -> Vec<PaperRow> {
    match dataset {
        "femnist" => vec![
            PaperRow { method: "No Compression", accuracy: "78.9% ± 0.12%", time_min: 3233.2, speedup: "1x" },
            PaperRow { method: "DGC", accuracy: "76.3% ± 0.43%", time_min: 102.4, speedup: "31x" },
            PaperRow { method: "FD + DGC", accuracy: "77.5% ± 0.24%", time_min: 82.3, speedup: "39x" },
            PaperRow { method: "AFD + DGC", accuracy: "80.6% ± 0.14%", time_min: 61.7, speedup: "52x" },
        ],
        "shakespeare" => vec![
            PaperRow { method: "No Compression", accuracy: "53.1% ± 0.22%", time_min: 762.5, speedup: "1x" },
            PaperRow { method: "DGC", accuracy: "52.8% ± 0.54%", time_min: 21.2, speedup: "36x" },
            PaperRow { method: "FD + DGC", accuracy: "52.5% ± 0.34%", time_min: 17.4, speedup: "44x" },
            PaperRow { method: "AFD + DGC", accuracy: "54.4% ± 0.36%", time_min: 13.3, speedup: "57x" },
        ],
        _ => vec![
            PaperRow { method: "No Compression", accuracy: "82.9% ± 0.19%", time_min: 3050.7, speedup: "1x" },
            PaperRow { method: "DGC", accuracy: "82.5% ± 0.29%", time_min: 89.7, speedup: "34x" },
            PaperRow { method: "FD + DGC", accuracy: "82.7% ± 0.11%", time_min: 76.2, speedup: "40x" },
            PaperRow { method: "AFD + DGC", accuracy: "83.8% ± 0.56%", time_min: 57.5, speedup: "53x" },
        ],
    }
}

fn main() -> anyhow::Result<()> {
    let seeds = env_usize("AFD_BENCH_SEEDS", 1);
    let clients = env_usize("AFD_BENCH_CLIENTS", 12);

    println!("== Table 1 (non-IID, Multi-Model AFD) ==");
    println!("scaled: seeds={seeds} clients={clients}\n");

    // Per-dataset horizons: the char-LSTM needs more rounds to leave its
    // warm-up plateau than the CNN (mirrors the paper's 1000/80/400
    // asymmetry, inverted by our scaled models' convergence speeds).
    for (preset, dataset, rounds_default, target) in [
        (Preset::FemnistSmallNonIid, "femnist", 30, 0.55),
        (Preset::ShakespeareSmallNonIid, "shakespeare", 90, 0.15),
        (Preset::Sent140SmallNonIid, "sent140", 70, 0.72),
    ] {
        let mut base = ExperimentConfig::preset(preset);
        base.rounds = env_usize("AFD_BENCH_ROUNDS", rounds_default);
        base.num_clients = clients;
        base.eval_every = (base.rounds / 12).max(1);
        base.target_accuracy = Some(target);
        let (rows, _) = run_grid(&base, "afd_multi", seeds)?;
        report_against_paper(
            &format!("Table 1 / {dataset} (non-IID)"),
            &rows,
            &paper_rows(dataset),
        );
        println!();
    }
    Ok(())
}
