//! Fig. 2: Top-1 accuracy curves (vs simulated time), non-IID datasets,
//! Multi-Model AFD against the three baselines.
//!
//! Emits the per-method (sim_seconds, accuracy) series the figure plots.
//! Scale up with AFD_BENCH_ROUNDS / AFD_BENCH_SEEDS.

use afd::bench::tables::{env_usize, print_curves, run_grid};
use afd::config::{ExperimentConfig, Preset};

fn main() -> anyhow::Result<()> {
    let seeds = env_usize("AFD_BENCH_SEEDS", 1);
    let clients = env_usize("AFD_BENCH_CLIENTS", 12);

    println!("== Fig. 2 (non-IID accuracy curves, Multi-Model AFD) ==\n");
    for (preset, dataset, rounds_default) in [
        (Preset::FemnistSmallNonIid, "femnist", 30),
        (Preset::ShakespeareSmallNonIid, "shakespeare", 90),
        (Preset::Sent140SmallNonIid, "sent140", 70),
    ] {
        let mut base = ExperimentConfig::preset(preset);
        base.rounds = env_usize("AFD_BENCH_ROUNDS", rounds_default);
        base.num_clients = clients;
        base.eval_every = (base.rounds / 15).max(1);
        println!("---- {dataset} (non-IID) ----");
        let (rows, all) = run_grid(&base, "afd_multi", seeds)?;
        print_curves(&all);
        // Fig. 2's qualitative content: at any fixed simulated time
        // budget, AFD+DGC's curve dominates No Compression's.
        let afd = &all[3].1[0];
        let none = &all[0].1[0];
        let budget = afd.total_sim_seconds();
        let afd_final = afd.best_accuracy();
        let none_at_budget = none
            .accuracy_curve()
            .iter()
            .take_while(|(t, _)| *t <= budget)
            .map(|(_, a)| *a)
            .fold(0.0, f64::max);
        println!(
            "\nat AFD's total budget ({}): AFD acc {:.3} vs NoComp acc {:.3}  [{}]",
            afd::util::human_duration(budget),
            afd_final,
            none_at_budget,
            if afd_final > none_at_budget { "ok" } else { "MISS" }
        );
        let _ = rows;
        println!();
    }
    Ok(())
}
