//! Hot-path micro benches (the §Perf targets): native training kernels
//! vs the retained scalar reference, plan-based vs legacy packing,
//! compression codecs, selection, aggregation — everything the
//! coordinator does per client-round besides the XLA execution itself.
//!
//! This is a *before/after harness*: the "before" side (scalar
//! `train_epoch`, `pack_values`/`unpack_values`) is retained in-tree,
//! so every run measures the speedup on the same machine and writes
//! the tracked baseline to `BENCH_hotpath.json` at the repo root —
//! epoch time, pack/unpack time, and allocations-per-epoch from a
//! counting allocator.

use afd::bench::Bencher;
use afd::compression::quant::HadamardQuant8;
use afd::compression::{dgc, DenseCodec, RawF32};
use afd::dropout::ScoreMap;
use afd::model::packing::{self, PackPlan, PlanCache};
use afd::model::submodel::SubModel;
use afd::runtime::native::{mlp_spec, NativeMlp};
use afd::runtime::{BatchInput, EpochData, ModelRuntime};
use afd::tensor::kernels::Workspace;
use afd::tensor::simd::{self, scalar};
use afd::transport::frame;
use afd::util::alloc_count::{self, CountingAllocator};
use afd::util::json::Json;
use afd::util::rng::Pcg64;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg64::new(0);
    println!(
        "simd dispatch: {} (cpu: {})",
        simd::active_name(),
        simd::cpu_features().join(",")
    );

    // Model-sized payload: femnist_small-like 105k params (420 KB).
    let n = 105_194;
    let params: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let bytes = 4 * n as u64;

    println!("-- downlink codecs ({} payload) --", afd::util::human_bytes(bytes));
    let raw = RawF32;
    b.run("raw_f32 encode", Some(bytes), || {
        std::hint::black_box(raw.encode(&params, 1));
    });
    let q = HadamardQuant8::default();
    b.run("quant8 encode (hadamard+int8)", Some(bytes), || {
        std::hint::black_box(q.encode(&params, 1));
    });
    let enc = q.encode(&params, 1);
    b.run("quant8 decode", Some(bytes), || {
        std::hint::black_box(q.decode(&enc, 1));
    });

    println!("\n-- uplink DGC ({} delta) --", afd::util::human_bytes(bytes));
    let delta: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.01)).collect();
    let mut st = dgc::DgcState::new(dgc::DgcConfig::default());
    b.run("dgc compress (topk+momentum)", Some(bytes), || {
        std::hint::black_box(st.compress(&delta));
    });
    let msg = st.compress(&delta);
    b.run("dgc decode", Some(msg.len() as u64), || {
        std::hint::black_box(dgc::decode(&msg));
    });

    // ---- native train_epoch: scalar reference vs kernels ------------
    println!("\n-- native train_epoch (d=784 h=256 c=62, batch 20 × 5) --");
    let tspec = mlp_spec("hot", 784, 256, 62, 20, 5, 0.05);
    let mlp = NativeMlp::new(tspec.clone());
    let init = mlp.init_params(0);
    let n_samples = tspec.num_batches * tspec.batch_size;
    let xs: Vec<f32> = (0..n_samples * 784)
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let ys: Vec<i32> = (0..n_samples).map(|_| rng.below(62) as i32).collect();
    let data = EpochData {
        xs: BatchInput::F32(xs),
        ys,
    };
    let tsm = {
        let kept = vec![rng.sample_indices(256, 192)];
        SubModel::from_kept_indices(&tspec, &kept)
    };
    let masks = tsm.masks_f32();
    let r_scalar = b.run("train_epoch scalar reference", None, || {
        std::hint::black_box(mlp.train_epoch_scalar(&init, &masks, &data, 0.05).unwrap());
    });
    let mut ws = Workspace::new();
    let mut p = init.clone();
    let r_kernel = b.run("train_epoch kernels+workspace", None, || {
        p.copy_from_slice(&init);
        std::hint::black_box(mlp.train_epoch_in(&mut ws, &mut p, &masks, &data, 0.05).unwrap());
    });
    // Allocations for one warmed epoch, via the counting allocator.
    p.copy_from_slice(&init);
    alloc_count::arm();
    mlp.train_epoch_in(&mut ws, &mut p, &masks, &data, 0.05).unwrap();
    let epoch_allocs = alloc_count::disarm();
    println!("train_epoch allocations after warm-up: {epoch_allocs}");

    // ---- packing: legacy one-shot vs PackPlan -----------------------
    println!("\n-- packing / sub-model ops (8k-unit MLP spec, FDR 25%) --");
    let spec = mlp_spec("bench", 256, 2048, 32, 10, 5, 0.1);
    let flat: Vec<f32> = (0..spec.num_params).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let sm = {
        let kept = vec![rng.sample_indices(2048, 1536)];
        SubModel::from_kept_indices(&spec, &kept)
    };
    let pack_bytes = 4 * spec.num_params as u64;
    let r_pack_legacy = b.run("pack_values (legacy)", Some(pack_bytes), || {
        std::hint::black_box(packing::pack_values(&spec, &flat, &sm));
    });
    let packed = packing::pack_values(&spec, &flat, &sm);
    let mut out = flat.clone();
    let r_unpack_legacy = b.run("unpack_values (legacy)", Some(4 * packed.len() as u64), || {
        packing::unpack_values(&spec, &packed, &sm, &mut out);
        std::hint::black_box(&out);
    });
    let r_mask_legacy = b.run("coordinate_mask (legacy)", None, || {
        std::hint::black_box(packing::coordinate_mask(&spec, &sm));
    });

    let plan = PackPlan::build(&spec, &sm);
    let mut pbuf = Vec::new();
    plan.pack_into(&flat, &mut pbuf); // warm the reusable buffer
    let r_pack_plan = b.run("PackPlan::pack_into", Some(pack_bytes), || {
        plan.pack_into(&flat, &mut pbuf);
        std::hint::black_box(&pbuf);
    });
    let r_unpack_plan = b.run("PackPlan::unpack_from", Some(4 * pbuf.len() as u64), || {
        plan.unpack_from(&pbuf, &mut out);
        std::hint::black_box(&out);
    });
    let mut cmask = vec![false; spec.num_params];
    let r_mask_plan = b.run("PackPlan::mark_coord_mask", None, || {
        plan.mark_coord_mask(&mut cmask);
        std::hint::black_box(&cmask);
    });
    b.run("PackPlan::build (cache miss)", None, || {
        std::hint::black_box(PackPlan::build(&spec, &sm));
    });
    let cache = PlanCache::default();
    let _ = cache.get(&spec, &sm);
    b.run("PlanCache::get (hit)", None, || {
        std::hint::black_box(cache.get(&spec, &sm));
    });
    alloc_count::arm();
    plan.pack_into(&flat, &mut pbuf);
    plan.unpack_from(&pbuf, &mut out);
    let pack_allocs = alloc_count::disarm();
    println!("plan pack+unpack allocations after warm-up: {pack_allocs}");

    // ---- SIMD primitives: dispatched vs retained scalar -------------
    // Both paths live in the same binary, so the recorded ratios are
    // machine-independent. Without `--features simd` (or no AVX2) the
    // dispatch IS scalar and every ratio is ~1.0 — the `simd.active`
    // field in the JSON says which case was measured.
    println!("\n-- simd primitives ({} dispatch) --", simd::active_name());
    let prim_n = 105_194usize;
    let pa: Vec<f32> = (0..prim_n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let pb: Vec<f32> = (0..prim_n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let prim_bytes = 4 * prim_n as u64;

    let mut out = pa.clone();
    let r_axpy_s = b.run("axpy_row scalar", Some(prim_bytes), || {
        scalar::axpy_row(&mut out, 0.37, &pb);
        std::hint::black_box(&out);
    });
    let r_axpy_d = b.run("axpy_row dispatched", Some(prim_bytes), || {
        simd::axpy_row(&mut out, 0.37, &pb);
        std::hint::black_box(&out);
    });

    let mut fw = pa[..4096].to_vec();
    let r_fwht_s = b.run("fwht 4096 scalar", Some(4 * 4096), || {
        scalar::fwht(&mut fw);
        std::hint::black_box(&fw);
    });
    let r_fwht_d = b.run("fwht 4096 dispatched", Some(4 * 4096), || {
        simd::fwht(&mut fw);
        std::hint::black_box(&fw);
    });

    let mut qout = vec![0u8; prim_n];
    let r_quant_s = b.run("quantize_block scalar", Some(prim_bytes), || {
        scalar::quantize_block(&pa, 12.7, &mut qout);
        std::hint::black_box(&qout);
    });
    let r_quant_d = b.run("quantize_block dispatched", Some(prim_bytes), || {
        simd::quantize_block(&pa, 12.7, &mut qout);
        std::hint::black_box(&qout);
    });

    let r_absmax_s = b.run("absmax scalar", Some(prim_bytes), || {
        std::hint::black_box(scalar::absmax(&pa));
    });
    let r_absmax_d = b.run("absmax dispatched", Some(prim_bytes), || {
        std::hint::black_box(simd::absmax(&pa));
    });

    let mut du = pa.clone();
    let mut dv = pb.clone();
    let r_scan_s = b.run("dgc_scan scalar", Some(prim_bytes), || {
        scalar::dgc_scan(&mut du, &mut dv, &pa, 0.9, 0.99);
        std::hint::black_box(&dv);
    });
    let r_scan_d = b.run("dgc_scan dispatched", Some(prim_bytes), || {
        simd::dgc_scan(&mut du, &mut dv, &pa, 0.9, 0.99);
        std::hint::black_box(&dv);
    });

    // ---- transport framing ------------------------------------------
    // The wire layer must be noise next to the codecs it frames: one
    // header+CRC pass over the payload per frame.
    println!(
        "\n-- transport frames ({} quant8 payload) --",
        afd::util::human_bytes(enc.wire_bytes())
    );
    let offer_sm = SubModel::from_kept_indices(&tspec, &[rng.sample_indices(256, 192)]);
    let mut fbuf = Vec::new();
    let r_offer_enc = b.run("encode RoundOffer (256-unit bitmap)", None, || {
        fbuf.clear();
        frame::encode_round_offer(&mut fbuf, 1, 2, 3, 0.05, f64::NAN, &offer_sm);
        std::hint::black_box(&fbuf);
    });
    let mut mbuf = Vec::new();
    let r_model_enc = b.run("encode ModelDown frame", Some(enc.wire_bytes()), || {
        mbuf.clear();
        frame::encode_model_down(&mut mbuf, 1, 2, 1, &enc.bytes);
        std::hint::black_box(&mbuf);
    });
    let r_frame_parse = b.run("parse ModelDown frame (CRC)", Some(enc.wire_bytes()), || {
        let (view, _) = frame::parse_frame(&mbuf).unwrap();
        std::hint::black_box(frame::parse_model_down(&view).unwrap());
    });

    println!("\n-- selection (2048-unit score map) --");
    let mut map = ScoreMap::zeros(&spec);
    map.credit(&sm, 0.5);
    b.run("weighted_select (keep 75%)", None, || {
        std::hint::black_box(map.weighted_select(&spec, 0.25, &mut rng));
    });
    b.run("uniform_select (keep 75%)", None, || {
        std::hint::black_box(ScoreMap::uniform_select(&spec, 0.25, &mut rng));
    });

    println!("\n-- aggregation (105k params, 9 clients) --");
    let mut agg = afd::aggregation::FedAvg::new(n);
    let cm = vec![true; n];
    b.run("fedavg add_masked x9 + finalize", Some(9 * bytes), || {
        agg.reset();
        for _ in 0..9 {
            agg.add_masked(&params, &cm, 50.0);
        }
        std::hint::black_box(agg.finalize(&params));
    });

    // ---- observability overhead -------------------------------------
    // The obs contract (rust/src/obs/): a disabled span site is a
    // relaxed load + branch; an enabled one is two Instant reads plus
    // relaxed atomic stores into a preallocated per-thread ring. The
    // traced-vs-untraced rows below re-measure the two hottest real
    // sites (train epoch, frame parse) with recording fully live so
    // the overhead is a measured ratio, not a claim.
    println!(
        "\n-- observability (trace feature {}) --",
        if cfg!(feature = "trace") { "on" } else { "off" }
    );
    afd::obs::register_thread();
    let r_span_off = b.run("span open+drop (disabled)", None, || {
        std::hint::black_box(afd::obs::span(afd::obs::Stage::Pack));
    });
    afd::obs::set_enabled(true);
    let r_span_on = b.run("span open+drop (enabled)", None, || {
        std::hint::black_box(afd::obs::span(afd::obs::Stage::Pack));
    });
    let r_mark_on = b.run("mark (enabled)", None, || {
        afd::obs::mark(afd::obs::Stage::RoundMark, 1, 2);
    });
    let r_kernel_traced = b.run("train_epoch kernels (tracing on)", None, || {
        p.copy_from_slice(&init);
        std::hint::black_box(mlp.train_epoch_in(&mut ws, &mut p, &masks, &data, 0.05).unwrap());
    });
    let r_parse_traced = b.run("parse ModelDown frame (tracing on)", Some(enc.wire_bytes()), || {
        let (view, _) = frame::parse_frame(&mbuf).unwrap();
        std::hint::black_box(frame::parse_model_down(&view).unwrap());
    });

    // ---- distributed telemetry --------------------------------------
    // The per-round cost a remote client pays to ship its telemetry
    // home (PR 10): after the first iteration drains the rings, every
    // encode is the quiet-process snapshot (four zero counts, 40 wire
    // bytes) — the steady-state floor of the side channel. The parse
    // row is the coordinator's cost to accept it.
    println!("\n-- distributed telemetry --");
    let mut shipper = afd::obs::remote::Shipper::new();
    let mut tele_buf: Vec<u8> = Vec::with_capacity(64 * 1024);
    shipper.encode_into(&mut tele_buf, 1); // drain backlog, warm the sink
    tele_buf.clear();
    shipper.encode_into(&mut tele_buf, 2);
    let r_tele_enc = b.run("telemetry snapshot encode (warm, quiet)", None, || {
        tele_buf.clear();
        shipper.encode_into(&mut tele_buf, 3);
        std::hint::black_box(tele_buf.len());
    });
    let tele_quiet_bytes = tele_buf.len();
    let r_tele_parse = b.run("telemetry frame parse (quiet)", None, || {
        let (view, _) = frame::parse_frame(&tele_buf).unwrap();
        std::hint::black_box(frame::parse_telemetry(&view).unwrap());
    });
    afd::obs::set_enabled(false);

    // ---- tracked baseline: BENCH_hotpath.json -----------------------
    let mut baseline = Json::obj();
    baseline.set("train_epoch_scalar_ns", Json::Num(r_scalar.median_ns));
    baseline.set("pack_values_ns", Json::Num(r_pack_legacy.median_ns));
    baseline.set("unpack_values_ns", Json::Num(r_unpack_legacy.median_ns));
    baseline.set("coordinate_mask_ns", Json::Num(r_mask_legacy.median_ns));
    let mut new = Json::obj();
    new.set("train_epoch_ns", Json::Num(r_kernel.median_ns));
    new.set("pack_into_ns", Json::Num(r_pack_plan.median_ns));
    new.set("unpack_from_ns", Json::Num(r_unpack_plan.median_ns));
    new.set("mark_coord_mask_ns", Json::Num(r_mask_plan.median_ns));
    let mut speedup = Json::obj();
    speedup.set(
        "train_epoch",
        Json::Num(r_scalar.median_ns / r_kernel.median_ns),
    );
    speedup.set(
        "pack",
        Json::Num(r_pack_legacy.median_ns / r_pack_plan.median_ns),
    );
    speedup.set(
        "unpack",
        Json::Num(r_unpack_legacy.median_ns / r_unpack_plan.median_ns),
    );
    let mut doc = Json::obj();
    doc.set("bench", Json::Str("bench_micro_hotpath".into()));
    doc.set(
        "note",
        Json::Str(
            "Before/after harness: `baseline` is the retained scalar train_epoch \
             reference and the legacy one-shot packing; `kernels` is the blocked \
             kernel + workspace path and PackPlan; `simd` records the detected CPU \
             features, the active dispatch level and dispatched-vs-scalar primitive \
             ratios; `obs` records the raw span-site cost (enabled vs disabled) and \
             tracing-on/off ratios for the two hottest instrumented sites; \
             `telemetry` records the steady-state cost of the distributed \
             telemetry side channel (warm quiet-snapshot encode + parse) — all \
             measured in the same run on the same machine. Regenerate \
             with `cargo bench --bench bench_micro_hotpath` (add `--features simd` \
             to measure the AVX2 dispatch)."
                .into(),
        ),
    );
    let mut targets = Json::obj();
    targets.set("train_epoch", Json::Num(3.0));
    targets.set("pack", Json::Num(5.0));
    targets.set("unpack", Json::Num(5.0));
    doc.set("targets", targets);
    doc.set(
        "train_config",
        Json::Str("d=784 h=256 c=62 batch=20 batches=5, keep 192/256".into()),
    );
    doc.set(
        "pack_config",
        Json::Str("d=256 h=2048 c=32, keep 1536/2048 (FDR 25%)".into()),
    );
    doc.set("baseline", baseline);
    doc.set("kernels", new);
    doc.set("speedup", speedup);
    doc.set(
        "allocations_per_epoch_after_warmup",
        Json::Num(epoch_allocs as f64),
    );
    doc.set(
        "allocations_per_pack_unpack_after_warmup",
        Json::Num(pack_allocs as f64),
    );
    let mut simd_j = Json::obj();
    simd_j.set("active", Json::Str(simd::active_name().into()));
    simd_j.set(
        "cpu_features",
        Json::Arr(
            simd::cpu_features()
                .iter()
                .map(|f| Json::Str((*f).to_string()))
                .collect(),
        ),
    );
    let mut prim = Json::obj();
    prim.set("axpy_row", Json::Num(r_axpy_s.median_ns / r_axpy_d.median_ns));
    prim.set("fwht", Json::Num(r_fwht_s.median_ns / r_fwht_d.median_ns));
    prim.set(
        "quantize_block",
        Json::Num(r_quant_s.median_ns / r_quant_d.median_ns),
    );
    prim.set(
        "absmax",
        Json::Num(r_absmax_s.median_ns / r_absmax_d.median_ns),
    );
    prim.set(
        "dgc_scan",
        Json::Num(r_scan_s.median_ns / r_scan_d.median_ns),
    );
    simd_j.set("primitive_speedup", prim);
    doc.set("simd", simd_j);
    let mut transport_j = Json::obj();
    transport_j.set("offer_encode_ns", Json::Num(r_offer_enc.median_ns));
    transport_j.set("model_frame_encode_ns", Json::Num(r_model_enc.median_ns));
    transport_j.set("frame_parse_crc_ns", Json::Num(r_frame_parse.median_ns));
    transport_j.set(
        "frame_overhead_bytes",
        Json::Num(frame::FRAME_OVERHEAD as f64),
    );
    doc.set("transport", transport_j);
    let mut obs_j = Json::obj();
    obs_j.set("trace_feature", Json::Bool(cfg!(feature = "trace")));
    obs_j.set("span_disabled_ns", Json::Num(r_span_off.median_ns));
    obs_j.set("span_enabled_ns", Json::Num(r_span_on.median_ns));
    obs_j.set("mark_enabled_ns", Json::Num(r_mark_on.median_ns));
    obs_j.set(
        "train_epoch_tracing_ratio",
        Json::Num(r_kernel_traced.median_ns / r_kernel.median_ns),
    );
    obs_j.set(
        "frame_parse_tracing_ratio",
        Json::Num(r_parse_traced.median_ns / r_frame_parse.median_ns),
    );
    doc.set("obs", obs_j);
    let mut tele_j = Json::obj();
    tele_j.set(
        "snapshot_encode_quiet_ns",
        Json::Num(r_tele_enc.median_ns),
    );
    tele_j.set("frame_parse_quiet_ns", Json::Num(r_tele_parse.median_ns));
    tele_j.set("quiet_frame_bytes", Json::Num(tele_quiet_bytes as f64));
    doc.set("telemetry", tele_j);
    doc.set("all_results", b.to_json());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_hotpath.json");
    std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_hotpath.json");
    println!("\nwrote {}", path.display());
    println!(
        "speedups: train_epoch {:.2}x, pack {:.2}x, unpack {:.2}x",
        r_scalar.median_ns / r_kernel.median_ns,
        r_pack_legacy.median_ns / r_pack_plan.median_ns,
        r_unpack_legacy.median_ns / r_unpack_plan.median_ns
    );
}
