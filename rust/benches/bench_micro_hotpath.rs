//! Hot-path micro benches (the §Perf targets): compression codecs,
//! packing, selection, aggregation — everything the coordinator does
//! per client-round besides the XLA execution itself.

use afd::bench::Bencher;
use afd::compression::quant::HadamardQuant8;
use afd::compression::{dgc, DenseCodec, RawF32};
use afd::dropout::ScoreMap;
use afd::model::packing;
use afd::model::submodel::SubModel;
use afd::runtime::native::mlp_spec;
use afd::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg64::new(0);

    // Model-sized payload: femnist_small-like 105k params (420 KB).
    let n = 105_194;
    let params: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let bytes = 4 * n as u64;

    println!("-- downlink codecs ({} payload) --", afd::util::human_bytes(bytes));
    let raw = RawF32;
    b.run("raw_f32 encode", Some(bytes), || {
        std::hint::black_box(raw.encode(&params, 1));
    });
    let q = HadamardQuant8::default();
    b.run("quant8 encode (hadamard+int8)", Some(bytes), || {
        std::hint::black_box(q.encode(&params, 1));
    });
    let enc = q.encode(&params, 1);
    b.run("quant8 decode", Some(bytes), || {
        std::hint::black_box(q.decode(&enc, 1));
    });

    println!("\n-- uplink DGC ({} delta) --", afd::util::human_bytes(bytes));
    let delta: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.01)).collect();
    let mut st = dgc::DgcState::new(dgc::DgcConfig::default());
    b.run("dgc compress (topk+momentum)", Some(bytes), || {
        std::hint::black_box(st.compress(&delta));
    });
    let msg = st.compress(&delta);
    b.run("dgc decode", Some(msg.len() as u64), || {
        std::hint::black_box(dgc::decode(&msg));
    });

    println!("\n-- packing / sub-model ops (8k-unit MLP spec) --");
    let spec = mlp_spec("bench", 256, 2048, 32, 10, 5, 0.1);
    let flat: Vec<f32> = (0..spec.num_params).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let sm = {
        let kept = vec![rng.sample_indices(2048, 1536)];
        SubModel::from_kept_indices(&spec, &kept)
    };
    b.run("pack_values (FDR 25%)", Some(4 * spec.num_params as u64), || {
        std::hint::black_box(packing::pack_values(&spec, &flat, &sm));
    });
    let packed = packing::pack_values(&spec, &flat, &sm);
    let mut out = flat.clone();
    b.run("unpack_values", Some(4 * packed.len() as u64), || {
        packing::unpack_values(&spec, &packed, &sm, &mut out);
        std::hint::black_box(&out);
    });
    b.run("coordinate_mask", None, || {
        std::hint::black_box(packing::coordinate_mask(&spec, &sm));
    });

    println!("\n-- selection (2048-unit score map) --");
    let mut map = ScoreMap::zeros(&spec);
    map.credit(&sm, 0.5);
    b.run("weighted_select (keep 75%)", None, || {
        std::hint::black_box(map.weighted_select(&spec, 0.25, &mut rng));
    });
    b.run("uniform_select (keep 75%)", None, || {
        std::hint::black_box(ScoreMap::uniform_select(&spec, 0.25, &mut rng));
    });

    println!("\n-- aggregation (105k params, 9 clients) --");
    let mut agg = afd::aggregation::FedAvg::new(n);
    let cm = vec![true; n];
    b.run("fedavg add_masked x9 + finalize", Some(9 * bytes), || {
        agg.reset();
        for _ in 0..9 {
            agg.add_masked(&params, &cm, 50.0);
        }
        std::hint::black_box(agg.finalize(&params));
    });

    println!("\n(JSON) {}", b.to_json().to_string_compact());
}
