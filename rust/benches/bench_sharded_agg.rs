//! Sharded-aggregation before/after harness → `BENCH_agg.json`.
//!
//! The "before" side is the retained single-threaded [`FedAvg`]
//! reference; the "after" side is [`ShardedFedAvg`] at several shard
//! counts, with both mask-based and pack-plan (contiguous-run) adds —
//! all measured in the same run on the same machine, so the recorded
//! speedups are machine-independent ratios. The payload is a
//! femnist-large-like ~1.18M-parameter MLP spec with a 16-client
//! cohort, the regime where aggregation is worth sharding.

use std::sync::Arc;

use afd::aggregation::{AddOp, FedAvg, HierarchicalFedAvg, ShardedFedAvg};
use afd::bench::Bencher;
use afd::model::packing::{coordinate_mask, PackPlan};
use afd::model::submodel::SubModel;
use afd::runtime::native::mlp_spec;
use afd::tensor::simd;
use afd::util::json::Json;
use afd::util::pool::LazyPool;
use afd::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg64::new(0);
    let pool = Arc::new(LazyPool::default_for_machine());

    // d=512 h=2048 c=64 ⇒ 512·2048 + 2048 + 2048·64 + 64 ≈ 1.18M params.
    let spec = mlp_spec("agg_bench", 512, 2048, 64, 10, 5, 0.1);
    let n = spec.num_params;
    let clients = 16usize;
    let sm = SubModel::from_kept_indices(&spec, &[rng.sample_indices(2048, 1536)]);
    let plan = PackPlan::build(&spec, &sm);
    let cm = coordinate_mask(&spec, &sm);
    let values: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let bytes = (clients * 4 * n) as u64;

    println!(
        "-- aggregation: {n} params x {clients} clients (keep 1536/2048), pool width {} --",
        pool.size()
    );

    let mut reference = FedAvg::new(n);
    let r_ref = b.run(
        "fedavg reference: add_masked x16 + finalize",
        Some(bytes),
        || {
            reference.reset();
            for _ in 0..clients {
                reference.add_masked(&values, &cm, 50.0);
            }
            std::hint::black_box(reference.finalize(&base));
        },
    );

    let mut shard_counts = vec![1usize, 2, 4, pool.size().max(1)];
    shard_counts.sort_unstable();
    shard_counts.dedup();

    let mut sharded_rows = Vec::new();
    let mut best_masked = f64::INFINITY;
    let mut best_planned = f64::INFINITY;
    let mut best_batched = f64::INFINITY;
    let mut best_shards = 0usize;
    for &shards in &shard_counts {
        let mut agg = ShardedFedAvg::new(n, shards, Arc::clone(&pool));
        let r_mask = b.run(
            &format!("sharded x{shards}: add_masked x16 + finalize"),
            Some(bytes),
            || {
                agg.reset();
                for _ in 0..clients {
                    agg.add_masked(&values, &cm, 50.0);
                }
                std::hint::black_box(agg.finalize(&base));
            },
        );
        let r_plan = b.run(
            &format!("sharded x{shards}: add_planned x16 + finalize"),
            Some(bytes),
            || {
                agg.reset();
                for _ in 0..clients {
                    agg.add_planned(&values, &plan, 50.0);
                }
                std::hint::black_box(agg.finalize(&base));
            },
        );
        // Persistent fan-out: the whole round (reset + 16 adds +
        // finalize) in ONE pool dispatch — shard workers stay pinned
        // across the adds (bit-identical to the per-add path,
        // rust/tests/agg_sharding.rs).
        let ops: Vec<AddOp> = (0..clients)
            .map(|_| AddOp::Planned {
                values: &values,
                plan: &plan,
                n_c: 50.0,
            })
            .collect();
        let mut out = Vec::new();
        let r_batch = b.run(
            &format!("sharded x{shards}: aggregate_batch x16 (1 dispatch)"),
            Some(bytes),
            || {
                agg.aggregate_batch(&ops, &base, &mut out);
                std::hint::black_box(&out);
            },
        );
        if r_mask.median_ns < best_masked {
            best_masked = r_mask.median_ns;
            best_shards = shards;
        }
        best_planned = best_planned.min(r_plan.median_ns);
        best_batched = best_batched.min(r_batch.median_ns);
        let mut row = Json::obj();
        row.set("shards", Json::Num(shards as f64));
        row.set("add_masked_finalize_ns", Json::Num(r_mask.median_ns));
        row.set("add_planned_finalize_ns", Json::Num(r_plan.median_ns));
        row.set("aggregate_batch_ns", Json::Num(r_batch.median_ns));
        sharded_rows.push(row);
    }

    // Hierarchical topology sweep at the same fixed cohort: flat (the
    // best sharded row above) vs 2-level and 3-level trees. The tree is
    // a coordinate-space topology knob — bit-identical to flat
    // (rust/tests/agg_hierarchy.rs) — so these rows measure pure
    // orchestration overhead/benefit of the extra merge level.
    let ops: Vec<AddOp> = (0..clients)
        .map(|_| AddOp::Planned {
            values: &values,
            plan: &plan,
            n_c: 50.0,
        })
        .collect();
    let mut hierarchy_rows = Vec::new();
    for (levels, fanout) in [(2usize, 4usize), (2, 8), (3, 2), (3, 4)] {
        let mut tree = HierarchicalFedAvg::new(n, levels, fanout, Arc::clone(&pool));
        let mut out = Vec::new();
        let r_tree = b.run(
            &format!("tree {levels}x{fanout}: aggregate_batch x16 (1 dispatch)"),
            Some(bytes),
            || {
                tree.aggregate_batch(&ops, &base, &mut out);
                std::hint::black_box(&out);
            },
        );
        let mut row = Json::obj();
        row.set("levels", Json::Num(levels as f64));
        row.set("fanout", Json::Num(fanout as f64));
        row.set("leaves", Json::Num(tree.leaf_count() as f64));
        row.set("aggregate_batch_ns", Json::Num(r_tree.median_ns));
        row.set(
            "vs_best_flat_batched",
            Json::Num(best_batched / r_tree.median_ns),
        );
        hierarchy_rows.push(row);
    }

    let mut doc = Json::obj();
    doc.set("bench", Json::Str("bench_sharded_agg".into()));
    doc.set(
        "note",
        Json::Str(
            "Before/after harness: `reference` is the retained single-threaded FedAvg \
             (add_masked x16 + finalize); `sharded` is ShardedFedAvg at each shard \
             count, mask-based and pack-plan (contiguous-run) adds; `hierarchy` is \
             HierarchicalFedAvg at each (levels, fanout) tree shape on the same \
             batched round — same machine, same run. Regenerate with \
             `cargo bench --bench bench_sharded_agg`."
                .into(),
        ),
    );
    doc.set(
        "config",
        Json::Str(format!(
            "d=512 h=2048 c=64 ({n} params), {clients} clients, keep 1536/2048, \
             pool width {}",
            pool.size()
        )),
    );
    let mut reference_j = Json::obj();
    reference_j.set("add_masked_finalize_ns", Json::Num(r_ref.median_ns));
    doc.set("reference", reference_j);
    doc.set("sharded", Json::Arr(sharded_rows));
    doc.set("hierarchy", Json::Arr(hierarchy_rows));
    let mut speedup = Json::obj();
    speedup.set("best_masked", Json::Num(r_ref.median_ns / best_masked));
    speedup.set("best_planned", Json::Num(r_ref.median_ns / best_planned));
    speedup.set("best_batched", Json::Num(r_ref.median_ns / best_batched));
    speedup.set("best_shards", Json::Num(best_shards as f64));
    doc.set("speedup", speedup);
    let mut simd_j = Json::obj();
    simd_j.set("active", Json::Str(simd::active_name().to_string()));
    simd_j.set(
        "cpu_features",
        Json::Arr(
            simd::cpu_features()
                .iter()
                .map(|f| Json::Str((*f).to_string()))
                .collect(),
        ),
    );
    doc.set("simd", simd_j);
    doc.set("all_results", b.to_json());

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_agg.json");
    std::fs::write(&path, doc.to_string_pretty()).expect("write BENCH_agg.json");
    println!("\nwrote {}", path.display());
    println!(
        "speedup vs reference: masked {:.2}x (at {} shards), planned {:.2}x",
        r_ref.median_ns / best_masked,
        best_shards,
        r_ref.median_ns / best_planned
    );
}
