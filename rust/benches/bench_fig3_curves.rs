//! Fig. 3: Top-1 accuracy curves, IID datasets, Single-Model AFD.
//!
//! Scale up with AFD_BENCH_ROUNDS / AFD_BENCH_SEEDS.

use afd::bench::tables::{env_usize, print_curves, run_grid};
use afd::config::{ExperimentConfig, Preset};

fn main() -> anyhow::Result<()> {
    let seeds = env_usize("AFD_BENCH_SEEDS", 1);
    let clients = env_usize("AFD_BENCH_CLIENTS", 20);

    println!("== Fig. 3 (IID accuracy curves, Single-Model AFD) ==\n");
    for (preset, dataset, rounds_default) in [
        (Preset::FemnistSmallIid, "femnist", 30),
        (Preset::ShakespeareSmallIid, "shakespeare", 90),
        (Preset::Sent140SmallIid, "sent140", 70),
    ] {
        let mut base = ExperimentConfig::preset(preset);
        base.rounds = env_usize("AFD_BENCH_ROUNDS", rounds_default);
        base.num_clients = clients;
        base.eval_every = (base.rounds / 15).max(1);
        println!("---- {dataset} (IID) ----");
        let (_, all) = run_grid(&base, "afd_single", seeds)?;
        print_curves(&all);
        // Fig. 3's content: compression curves track NoComp accuracy
        // with at most minor degradation, and Single-Model AFD matches
        // or beats the other compressed methods at its own budget.
        let afd_acc = all[3].1[0].best_accuracy();
        let fd_acc = all[2].1[0].best_accuracy();
        println!(
            "\nSingle-Model AFD {:.3} vs FD {:.3}  [{}]",
            afd_acc,
            fd_acc,
            if afd_acc >= fd_acc - 0.02 { "ok" } else { "MISS" }
        );
        println!();
    }
    Ok(())
}
