//! Fig. 4: Top-1 accuracy of Multi-Model AFD vs FD as the fraction of
//! clients per round varies (non-IID FEMNIST).
//!
//! Paper shape: at small fractions AFD ≈ FD (score maps update too
//! rarely); the AFD advantage appears as the fraction grows, flattening
//! past ~30-35%.
//!
//! Scale up with AFD_BENCH_ROUNDS / AFD_BENCH_SEEDS.

use afd::bench::tables::env_usize;
use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;
use afd::util::stats;

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("AFD_BENCH_ROUNDS", 30);
    let seeds = env_usize("AFD_BENCH_SEEDS", 2);
    let clients = env_usize("AFD_BENCH_CLIENTS", 20);
    let fractions = [0.1, 0.2, 0.3, 0.5];

    println!("== Fig. 4 (accuracy vs client fraction, non-IID FEMNIST) ==");
    println!("rounds={rounds} seeds={seeds} clients={clients}\n");
    println!(
        "{:<10} {:>16} {:>16} {:>10}",
        "fraction", "AFD (multi)", "FD", "AFD-FD"
    );

    let mut gaps = Vec::new();
    for &f in &fractions {
        let mut afd_accs = Vec::new();
        let mut fd_accs = Vec::new();
        for s in 0..seeds as u64 {
            for (dropout, bucket) in
                [("afd_multi", &mut afd_accs), ("fd", &mut fd_accs)]
            {
                let mut cfg = ExperimentConfig::preset(Preset::FemnistSmallNonIid);
                cfg.rounds = rounds;
                cfg.num_clients = clients;
                cfg.client_fraction = f;
                cfg.dropout = dropout.into();
                cfg.eval_every = (rounds / 10).max(1);
                cfg.seed = s;
                bucket.push(run_experiment(&cfg)?.best_accuracy());
            }
        }
        let (am, fm) = (stats::mean(&afd_accs), stats::mean(&fd_accs));
        println!(
            "{:<10.2} {:>9.3} ±{:.3} {:>9.3} ±{:.3} {:>+10.3}",
            f,
            am,
            stats::std(&afd_accs),
            fm,
            stats::std(&fd_accs),
            am - fm
        );
        gaps.push(am - fm);
    }

    // Shape check: the AFD advantage at the largest fraction exceeds the
    // advantage at the smallest (score maps need participation).
    let ok = *gaps.last().unwrap() >= gaps.first().unwrap() - 0.01;
    println!(
        "\nshape: AFD-FD gap grows with fraction (small {:.3} -> large {:.3})  [{}]",
        gaps.first().unwrap(),
        gaps.last().unwrap(),
        if ok { "ok" } else { "MISS" }
    );
    Ok(())
}
