//! Table 2: accuracy + convergence time + speedup, **IID** datasets,
//! Single-Model AFD, 10% of clients per round.
//!
//! Scale up with: AFD_BENCH_ROUNDS=120 AFD_BENCH_SEEDS=3 cargo bench

use afd::bench::tables::{env_usize, report_against_paper, run_grid, PaperRow};
use afd::config::{ExperimentConfig, Preset};

fn paper_rows(dataset: &str) -> Vec<PaperRow> {
    match dataset {
        "femnist" => vec![
            PaperRow { method: "No Compression", accuracy: "83.9% ± 0.09%", time_min: 3119.9, speedup: "1x" },
            PaperRow { method: "DGC", accuracy: "83.6% ± 0.27%", time_min: 84.9, speedup: "37x" },
            PaperRow { method: "FD + DGC", accuracy: "84.1% ± 0.72%", time_min: 65.7, speedup: "48x" },
            PaperRow { method: "AFD + DGC", accuracy: "86.2% ± 0.55%", time_min: 58.1, speedup: "53x" },
        ],
        "shakespeare" => vec![
            PaperRow { method: "No Compression", accuracy: "52.2% ± 0.18%", time_min: 705.7, speedup: "1x" },
            PaperRow { method: "DGC", accuracy: "50.8% ± 0.85%", time_min: 25.6, speedup: "28x" },
            PaperRow { method: "FD + DGC", accuracy: "50.9% ± 0.72%", time_min: 16.9, speedup: "48x" },
            PaperRow { method: "AFD + DGC", accuracy: "53.7% ± 0.65%", time_min: 12.4, speedup: "57x" },
        ],
        _ => vec![
            PaperRow { method: "No Compression", accuracy: "84.7% ± 0.16%", time_min: 2893.4, speedup: "1x" },
            PaperRow { method: "DGC", accuracy: "84.5% ± 0.77%", time_min: 82.6, speedup: "35x" },
            PaperRow { method: "FD + DGC", accuracy: "84.5% ± 0.39%", time_min: 68.8, speedup: "42x" },
            PaperRow { method: "AFD + DGC", accuracy: "85.3% ± 0.75%", time_min: 52.6, speedup: "55x" },
        ],
    }
}

fn main() -> anyhow::Result<()> {
    let seeds = env_usize("AFD_BENCH_SEEDS", 1);
    let clients = env_usize("AFD_BENCH_CLIENTS", 20);

    println!("== Table 2 (IID, Single-Model AFD, 10% clients/round) ==");
    println!("scaled: seeds={seeds} clients={clients}\n");

    for (preset, dataset, rounds_default, target) in [
        (Preset::FemnistSmallIid, "femnist", 30, 0.60),
        (Preset::ShakespeareSmallIid, "shakespeare", 90, 0.15),
        (Preset::Sent140SmallIid, "sent140", 70, 0.72),
    ] {
        let mut base = ExperimentConfig::preset(preset);
        base.rounds = env_usize("AFD_BENCH_ROUNDS", rounds_default);
        base.num_clients = clients;
        base.eval_every = (base.rounds / 12).max(1);
        base.target_accuracy = Some(target);
        let (rows, _) = run_grid(&base, "afd_single", seeds)?;
        report_against_paper(
            &format!("Table 2 / {dataset} (IID)"),
            &rows,
            &paper_rows(dataset),
        );
        println!();
    }
    Ok(())
}
