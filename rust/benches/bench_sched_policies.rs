//! Scheduler policy comparison: time-to-target-accuracy for
//! Sync vs Overselect vs AsyncBuffered under straggler-heavy links.
//!
//! The paper's convergence-time metric makes every synchronous round
//! as slow as its slowest client; this bench quantifies what the two
//! standard straggler levers buy on the artifact-free native workload
//! (log-uniform link fleet — see `LinkConfig::straggler_heavy`).
//!
//! Scale up with: AFD_BENCH_ROUNDS=120 AFD_BENCH_SEEDS=3 \
//!   cargo bench --bench bench_sched_policies

use afd::bench::tables::env_usize;
use afd::config::{ExperimentConfig, Preset};
use afd::coordinator::experiment::run_experiment;
use afd::network::LinkConfig;
use afd::util::{human_bytes, human_duration};

fn main() -> anyhow::Result<()> {
    let seeds = env_usize("AFD_BENCH_SEEDS", 2) as u64;
    let rounds = env_usize("AFD_BENCH_ROUNDS", 60);
    let target = 0.45;

    println!("== Scheduler policies (native, straggler-heavy links) ==");
    println!("rounds={rounds} seeds={seeds} target accuracy={target}\n");
    println!(
        "{:<16} {:>9} {:>14} {:>14} {:>12} {:>10} {:>8}",
        "policy", "best acc", "t(target)", "total sim", "down", "cut", "speedup"
    );

    let mut t_per_policy = Vec::new();
    for policy in ["sync", "overselect", "async_buffered"] {
        let mut t_target = 0.0f64;
        let mut t_total = 0.0f64;
        let mut best = 0.0f64;
        let mut down = 0u64;
        let mut cut = 0usize;
        let mut reached = 0usize;
        for seed in 0..seeds {
            let mut cfg = ExperimentConfig::preset(Preset::NativeSmoke);
            cfg.rounds = rounds;
            cfg.eval_every = 2;
            cfg.seed = seed;
            cfg.link = LinkConfig::straggler_heavy();
            cfg.sched.policy = policy.into();
            let r = run_experiment(&cfg)?;
            if let Some((_, t)) = r.time_to_accuracy(target, 1) {
                t_target += t;
                reached += 1;
            }
            t_total += r.total_sim_seconds();
            best = best.max(r.best_accuracy());
            down += r.total_down_bytes();
            cut += r.records.iter().map(|rec| rec.cut).sum::<usize>();
        }
        let t_shown = if reached == seeds as usize {
            t_target
        } else {
            f64::INFINITY
        };
        t_per_policy.push((policy, t_shown));
        let speedup = match t_per_policy.first() {
            Some((_, base)) if t_shown.is_finite() && base.is_finite() && *base > 0.0 => {
                format!("{:.1}x", base / t_shown)
            }
            _ => "-".into(),
        };
        println!(
            "{:<16} {:>9.3} {:>14} {:>14} {:>12} {:>10} {:>8}",
            policy,
            best,
            if t_shown.is_finite() {
                human_duration(t_shown)
            } else {
                format!("not reached ({reached}/{seeds})")
            },
            human_duration(t_total),
            human_bytes(down),
            cut,
            speedup
        );
    }

    // The subsystem's acceptance assertion: both straggler policies
    // must reach the target in less simulated time than sync.
    let t_sync = t_per_policy[0].1;
    let t_over = t_per_policy[1].1;
    let t_async = t_per_policy[2].1;
    anyhow::ensure!(
        t_sync.is_finite(),
        "sync never reached the target accuracy — nothing was measured"
    );
    anyhow::ensure!(
        t_over < t_sync,
        "overselect must beat sync: {t_over:.1}s vs {t_sync:.1}s"
    );
    anyhow::ensure!(
        t_async < t_sync,
        "async_buffered must beat sync: {t_async:.1}s vs {t_sync:.1}s"
    );
    println!("\nOK: both straggler policies beat sync to {target} accuracy.");
    Ok(())
}
