//! PJRT runtime bench: the XLA boundary of the per-client hot path —
//! literal building, train-epoch execution, eval execution — plus the
//! Pallas-kernel artifacts raced against the native Rust twins.
//!
//! Requires `make artifacts` (skips politely otherwise).

use afd::bench::Bencher;
use afd::compression::quant::HadamardQuant8;
use afd::compression::DenseCodec;
use afd::model::manifest::{DType, Manifest};
use afd::model::submodel::SubModel;
use afd::runtime::pjrt::{compile_kernel_artifact, PjrtRuntime};
use afd::runtime::{BatchInput, EpochData, EvalBatch, ModelRuntime};
use afd::util::rng::Pcg64;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime_exec: artifacts not built, skipping");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    let mut b = Bencher::default();
    let mut rng = Pcg64::new(0);

    for name in ["femnist_small", "shakespeare_small", "sent140_small"] {
        if !manifest.variants.contains_key(name) {
            continue;
        }
        let rt = PjrtRuntime::load(&client, &manifest, name).unwrap();
        let spec = rt.spec().clone();
        let params = manifest.load_init_params(&spec).unwrap();
        let sm = SubModel::full(&spec);
        let masks = sm.masks_f32();

        let per: usize = spec.input_shape.iter().product();
        let nsamples = spec.samples_per_round();
        let data = EpochData {
            xs: match spec.input_dtype {
                DType::F32 => BatchInput::F32(
                    (0..nsamples * per).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                ),
                DType::I32 => BatchInput::I32(
                    (0..nsamples * per)
                        .map(|_| rng.below(spec.vocab.max(2) as u64) as i32)
                        .collect(),
                ),
            },
            ys: (0..nsamples)
                .map(|_| rng.below(spec.classes as u64) as i32)
                .collect(),
        };
        println!("\n-- {name}: train epoch ({} samples) --", nsamples);
        b.run(&format!("{name} train_epoch (PJRT)"), None, || {
            std::hint::black_box(
                rt.train_epoch(&params, &masks, &data, spec.lr).unwrap(),
            );
        });
        let batch = EvalBatch {
            xs: match &data.xs {
                BatchInput::F32(v) => BatchInput::F32(v[..spec.batch_size * per].to_vec()),
                BatchInput::I32(v) => BatchInput::I32(v[..spec.batch_size * per].to_vec()),
            },
            ys: data.ys[..spec.batch_size].to_vec(),
        };
        b.run(&format!("{name} evaluate (PJRT)"), None, || {
            std::hint::black_box(rt.evaluate(&params, &batch).unwrap());
        });
    }

    // ---- L1 kernel artifact vs native Rust twin ----------------------
    if let Some(k) = manifest.kernels.clone() {
        println!("\n-- hadamard quant roundtrip: Pallas artifact vs native Rust --");
        let exe =
            compile_kernel_artifact(&client, &manifest, &k.hadamard_hlo).unwrap();
        let len = k.hadamard_len;
        let xs: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let signs = Pcg64::new(9).rademacher(len);
        let bytes = 4 * len as u64;
        b.run("hadamard roundtrip (Pallas/XLA)", Some(bytes), || {
            let lits = [
                afd::runtime::literal::f32_literal(&xs, &[len]).unwrap(),
                afd::runtime::literal::f32_literal(&signs, &[len]).unwrap(),
            ];
            let res = exe.execute::<xla::Literal>(&lits).unwrap();
            std::hint::black_box(res[0][0].to_literal_sync().unwrap());
        });
        let codec = HadamardQuant8::new(k.hadamard_block);
        b.run("hadamard roundtrip (native rust)", Some(bytes), || {
            let enc = codec.encode(&xs, 7);
            std::hint::black_box(codec.decode(&enc, 7));
        });
    }

    println!("\n(JSON) {}", b.to_json().to_string_compact());
}
